//! MSMR — Minimize Sparsity, Maximize Relevance feature selection.
//!
//! After the sparsity screen, MSMR (Estiri et al. 2020) ranks the
//! surviving sequences by **joint mutual information** against the
//! phenotype label and keeps the top-K (the MLHO vignette uses K = 200).
//! This implementation follows the JMI family: greedy forward selection
//! maximising `MI(f; y) − mean_{s ∈ selected} MI(f; s)` — relevance minus
//! redundancy — where all MI terms come from 2×2 contingency tables over
//! the binary patient×sequence matrix.
//!
//! MSMR consumes the CSR matrix wherever it came from — the in-memory
//! [`SeqMatrix::build`] or the index-fed
//! [`SeqMatrix::from_index`](crate::matrix::SeqMatrix::from_index)
//! (bit-identical by contract), so the spilled
//! `mine → screen → index → matrix → msmr` engine chain needs no MSMR
//! changes: the matrix is the memory boundary, not the record multiset.
//!
//! The count contractions (`Xᵀ·y` for relevance, `Xᵀ·X` over the
//! candidate pool for redundancy) are the dense hot-spot; when an
//! [`ArtifactSet`] is supplied they run on the AOT-compiled Pallas
//! kernel via PJRT (`cooc`, `cooc_label` artifacts), tiled and
//! accumulated across the patient dimension. A pure-Rust path computes
//! the same numbers for artifact-less runs and as the test oracle.

use crate::matrix::SeqMatrix;
use crate::runtime::{ArtifactSet, RuntimeError, Tensor};

/// Selection configuration.
#[derive(Clone, Copy, Debug)]
pub struct MsmrConfig {
    /// Features to keep.
    pub top_k: usize,
    /// Candidate pool ranked by relevance before the greedy pass
    /// (bounds the F×F redundancy matrix).
    pub pool_size: usize,
    /// Redundancy weight β in `MI(f;y) − β·mean MI(f;s)`.
    pub beta: f64,
}

impl Default for MsmrConfig {
    fn default() -> Self {
        MsmrConfig { top_k: 200, pool_size: 256, beta: 1.0 }
    }
}

/// Selection result.
#[derive(Clone, Debug)]
pub struct Selection {
    /// Chosen columns (indices into the input matrix), selection order.
    pub columns: Vec<u32>,
    /// Relevance MI(f; y) per chosen column.
    pub relevance: Vec<f64>,
}

/// Mutual information of a 2×2 contingency table given `n11`, the
/// marginals `ci`, `cj`, and the total `n` (natural log; the convention
/// 0·log(0/·) = 0). Mirrors `python/compile/kernels/ref.py::mi_pair_ref`.
pub fn mi_from_counts(n11: f64, ci: f64, cj: f64, n: f64) -> f64 {
    debug_assert!(n > 0.0);
    let n10 = ci - n11;
    let n01 = cj - n11;
    let n00 = n - ci - cj + n11;
    let term = |nab: f64, pa: f64, pb: f64| -> f64 {
        if nab > 0.0 && pa > 0.0 && pb > 0.0 {
            (nab / n) * ((nab * n) / (pa * pb)).ln()
        } else {
            0.0
        }
    };
    let mi = term(n11, ci, cj)
        + term(n10, ci, n - cj)
        + term(n01, n - ci, cj)
        + term(n00, n - ci, n - cj);
    mi.max(0.0)
}

/// Per-feature label co-occurrence counts `n11[f] = #{p : X[p,f]=1 ∧ y[p]=1}`.
///
/// Pure-Rust path over the CSR matrix.
pub fn label_counts_rust(m: &SeqMatrix, labels: &[f32]) -> Vec<f64> {
    assert_eq!(labels.len(), m.num_patients as usize);
    let mut n11 = vec![0f64; m.num_cols()];
    for pid in 0..m.num_patients as usize {
        if labels[pid] > 0.5 {
            for &c in &m.col_idx[m.row_ptr[pid]..m.row_ptr[pid + 1]] {
                n11[c as usize] += 1.0;
            }
        }
    }
    n11
}

/// Pairwise co-occurrence counts over a column subset (pool × pool),
/// pure-Rust path (sparse row intersection via dense marker).
pub fn pair_counts_rust(m: &SeqMatrix, pool: &[u32]) -> Vec<f64> {
    let k = pool.len();
    let mut pos_in_pool = vec![usize::MAX; m.num_cols()];
    for (i, &c) in pool.iter().enumerate() {
        pos_in_pool[c as usize] = i;
    }
    let mut counts = vec![0f64; k * k];
    let mut present: Vec<usize> = Vec::new();
    for pid in 0..m.num_patients as usize {
        present.clear();
        for &c in &m.col_idx[m.row_ptr[pid]..m.row_ptr[pid + 1]] {
            let p = pos_in_pool[c as usize];
            if p != usize::MAX {
                present.push(p);
            }
        }
        for (ai, &a) in present.iter().enumerate() {
            for &b in &present[ai..] {
                counts[a * k + b] += 1.0;
                if a != b {
                    counts[b * k + a] += 1.0;
                }
            }
        }
    }
    counts
}

/// Label co-occurrence counts via the PJRT `cooc_label` artifact,
/// accumulating over row tiles.
pub fn label_counts_pjrt(
    m: &SeqMatrix,
    labels: &[f32],
    arts: &ArtifactSet,
) -> Result<Vec<f64>, RuntimeError> {
    let (tp, tf) = (arts.tile_rows, arts.tile_features);
    let artifact = arts.get("cooc_label")?;
    let mut n11 = vec![0f64; m.num_cols()];
    let rows = m.num_patients as usize;
    for row0 in (0..rows).step_by(tp) {
        // Label tile (zero-padded → padded rows contribute nothing).
        let mut y = vec![0f32; tp];
        for i in 0..tp.min(rows - row0) {
            y[i] = labels[row0 + i];
        }
        let y = Tensor::new(vec![tp, 1], y);
        for col0 in (0..m.num_cols()).step_by(tf) {
            let x = Tensor::new(vec![tp, tf], m.dense_tile(row0 as u32, tp, col0 as u32, tf));
            let out = artifact.run(&[x, y.clone()])?;
            for (i, v) in out[0].data.iter().enumerate() {
                if col0 + i < m.num_cols() {
                    n11[col0 + i] += *v as f64;
                }
            }
        }
    }
    Ok(n11)
}

/// Pool × pool co-occurrence via the PJRT `cooc` artifact. The pool is
/// padded to one feature tile (pool_size ≤ tile_features).
pub fn pair_counts_pjrt(
    m: &SeqMatrix,
    pool: &[u32],
    arts: &ArtifactSet,
) -> Result<Vec<f64>, RuntimeError> {
    let (tp, tf) = (arts.tile_rows, arts.tile_features);
    assert!(pool.len() <= tf, "pool must fit one feature tile");
    let artifact = arts.get("cooc")?;
    let sub = m.select_columns(pool);
    let k = pool.len();
    let mut counts = vec![0f64; k * k];
    let rows = m.num_patients as usize;
    for row0 in (0..rows).step_by(tp) {
        let x = Tensor::new(vec![tp, tf], sub.dense_tile(row0 as u32, tp, 0, tf));
        let out = artifact.run(&[x.clone(), x])?;
        for a in 0..k {
            for b in 0..k {
                counts[a * k + b] += out[0].data[a * tf + b] as f64;
            }
        }
    }
    Ok(counts)
}

/// Run MSMR selection. `labels[p] ∈ {0,1}` per dense patient id; with
/// `artifacts` the contractions run on PJRT, otherwise pure Rust.
pub fn select(
    m: &SeqMatrix,
    labels: &[f32],
    cfg: &MsmrConfig,
    artifacts: Option<&ArtifactSet>,
) -> Result<Selection, RuntimeError> {
    let n = m.num_patients as f64;
    let n_cols = m.num_cols();
    if n_cols == 0 || m.num_patients == 0 {
        return Ok(Selection { columns: Vec::new(), relevance: Vec::new() });
    }
    let col_counts: Vec<f64> = m.col_counts().iter().map(|&c| c as f64).collect();
    let npos: f64 = labels.iter().filter(|&&v| v > 0.5).count() as f64;

    // 1. Relevance MI(f; y). Label counts are a *sparse* contraction
    // (one CSR scan over the nnz), so they stay on L3 regardless of
    // artifacts — densifying every feature tile to feed the accelerator
    // costs orders of magnitude more than the count itself (perf pass,
    // EXPERIMENTS.md §Perf). The dense work PJRT is for is the pool×pool
    // co-occurrence below. `label_counts_pjrt` remains available and
    // parity-tested for callers whose matrices are already dense.
    let n11 = label_counts_rust(m, labels);
    let relevance: Vec<f64> = (0..n_cols)
        .map(|f| mi_from_counts(n11[f], col_counts[f], npos, n))
        .collect();

    // 2. Candidate pool: top `pool_size` by relevance.
    let pool_size = cfg.pool_size.min(n_cols);
    let mut order: Vec<u32> = (0..n_cols as u32).collect();
    order.sort_by(|&a, &b| {
        relevance[b as usize]
            .partial_cmp(&relevance[a as usize])
            .unwrap()
            .then(a.cmp(&b))
    });
    let pool: Vec<u32> = order[..pool_size].to_vec();

    // 3. Redundancy matrix over the pool.
    let pair = match artifacts {
        Some(a) => pair_counts_pjrt(m, &pool, a)?,
        None => pair_counts_rust(m, &pool),
    };
    let k = pool.len();
    let mi_pair = |a: usize, b: usize| -> f64 {
        mi_from_counts(
            pair[a * k + b],
            col_counts[pool[a] as usize],
            col_counts[pool[b] as usize],
            n,
        )
    };

    // 4. Greedy forward selection.
    let top_k = cfg.top_k.min(k);
    let mut selected: Vec<usize> = Vec::with_capacity(top_k);
    let mut in_sel = vec![false; k];
    // redundancy_sum[i] = Σ_{s ∈ selected} MI(pool[i]; pool[s])
    let mut redundancy_sum = vec![0f64; k];
    for _ in 0..top_k {
        // (index, score, redundancy); ties on score break toward the
        // *less redundant* candidate — a fully redundant duplicate must
        // never beat an uninformative-but-novel feature at equal score.
        let mut best: Option<(usize, f64, f64)> = None;
        for i in 0..k {
            if in_sel[i] {
                continue;
            }
            let red = if selected.is_empty() {
                0.0
            } else {
                redundancy_sum[i] / selected.len() as f64
            };
            let score = relevance[pool[i] as usize] - cfg.beta * red;
            let better = match best {
                None => true,
                Some((_, s, r)) => score > s + 1e-12 || (score > s - 1e-12 && red < r),
            };
            if better {
                best = Some((i, score, red));
            }
        }
        let (chosen, _, _) = best.expect("non-empty pool");
        in_sel[chosen] = true;
        selected.push(chosen);
        for i in 0..k {
            if !in_sel[i] {
                redundancy_sum[i] += mi_pair(i, chosen);
            }
        }
    }

    Ok(Selection {
        relevance: selected.iter().map(|&i| relevance[pool[i] as usize]).collect(),
        columns: selected.into_iter().map(|i| pool[i]).collect(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mining::SeqRecord;
    use crate::rng::Rng;

    fn rec(seq: u64, pid: u32) -> SeqRecord {
        SeqRecord { seq, pid, duration: 0 }
    }

    /// 40 patients; label = patient < 20.
    /// col A (seq 10): perfect predictor. col B (seq 20): copy of A
    /// (fully redundant). col C (seq 30): random. col D (seq 40): weak.
    fn synthetic() -> (SeqMatrix, Vec<f32>) {
        let mut records = Vec::new();
        let mut r = Rng::new(5);
        for pid in 0..40u32 {
            let positive = pid < 20;
            if positive {
                records.push(rec(10, pid));
                records.push(rec(20, pid));
            }
            if r.gen_bool(0.5) {
                records.push(rec(30, pid));
            }
            if positive && r.gen_bool(0.8) || (!positive && r.gen_bool(0.2)) {
                records.push(rec(40, pid));
            }
        }
        let m = SeqMatrix::build(&records, 40).unwrap();
        let labels: Vec<f32> = (0..40).map(|p| f32::from(p < 20)).collect();
        (m, labels)
    }

    #[test]
    fn mi_from_counts_basics() {
        // perfect association: MI = H(y) = ln 2 for balanced y
        let mi = mi_from_counts(20.0, 20.0, 20.0, 40.0);
        assert!((mi - (2f64).ln()).abs() < 1e-9, "{mi}");
        // independence: factorised table
        assert!(mi_from_counts(10.0, 20.0, 20.0, 40.0).abs() < 1e-12);
        // degenerate: feature never fires
        assert_eq!(mi_from_counts(0.0, 0.0, 20.0, 40.0), 0.0);
    }

    #[test]
    fn mi_matches_python_oracle_values() {
        // Spot values cross-checked against kernels/ref.py::mi_pair_ref.
        let got = mi_from_counts(15.0, 20.0, 25.0, 40.0);
        assert!(got > 0.0 && got < (2f64).ln());
    }

    #[test]
    fn perfect_predictor_ranks_first() {
        let (m, labels) = synthetic();
        let sel = select(&m, &labels, &MsmrConfig { top_k: 2, pool_size: 4, beta: 1.0 }, None)
            .unwrap();
        let first_seq = m.seq_ids[sel.columns[0] as usize];
        assert!(first_seq == 10 || first_seq == 20, "first pick {first_seq}");
        // The redundant copy must NOT be second: redundancy pushes it out.
        let second_seq = m.seq_ids[sel.columns[1] as usize];
        assert!(second_seq != 10 && second_seq != 20, "second pick {second_seq}");
    }

    #[test]
    fn no_redundancy_penalty_keeps_duplicate() {
        let (m, labels) = synthetic();
        let sel = select(&m, &labels, &MsmrConfig { top_k: 2, pool_size: 4, beta: 0.0 }, None)
            .unwrap();
        let seqs: Vec<u64> = sel.columns.iter().map(|&c| m.seq_ids[c as usize]).collect();
        assert_eq!(seqs.iter().filter(|&&s| s == 10 || s == 20).count(), 2);
    }

    #[test]
    fn top_k_clamped_to_pool() {
        let (m, labels) = synthetic();
        let sel = select(&m, &labels, &MsmrConfig { top_k: 100, pool_size: 3, beta: 1.0 }, None)
            .unwrap();
        assert_eq!(sel.columns.len(), 3);
    }

    #[test]
    fn empty_matrix_selects_nothing() {
        let m = SeqMatrix::build(&[], 10).unwrap();
        let sel = select(&m, &vec![0.0; 10], &MsmrConfig::default(), None).unwrap();
        assert!(sel.columns.is_empty());
    }

    #[test]
    fn rust_count_paths_are_consistent() {
        let (m, labels) = synthetic();
        let n11 = label_counts_rust(&m, &labels);
        // col for seq 10 fires for exactly the 20 positives
        let col10 = m.seq_ids.iter().position(|&s| s == 10).unwrap();
        assert_eq!(n11[col10], 20.0);
        let pool: Vec<u32> = (0..m.num_cols() as u32).collect();
        let pair = pair_counts_rust(&m, &pool);
        let k = pool.len();
        // diagonal equals column counts
        let counts = m.col_counts();
        for i in 0..k {
            assert_eq!(pair[i * k + i], counts[i] as f64);
        }
        // symmetry
        for a in 0..k {
            for b in 0..k {
                assert_eq!(pair[a * k + b], pair[b * k + a]);
            }
        }
    }

    #[test]
    fn relevance_is_monotone_in_selection_quality() {
        let (m, labels) = synthetic();
        let sel =
            select(&m, &labels, &MsmrConfig { top_k: 4, pool_size: 4, beta: 0.0 }, None).unwrap();
        for w in sel.relevance.windows(2) {
            assert!(w[0] >= w[1] - 1e-12, "beta=0 must select by pure relevance order");
        }
    }
}
