//! `tspm` — the tSPM+ launcher.
//!
//! Subcommands:
//!
//! * `synth`     — generate a synthetic clinical dbmart (CSV + truth)
//! * `mine`      — mine transitive sequences from a dbmart CSV
//! * `screen`    — sparsity-screen a mined sequence file
//! * `postcovid` — vignette 2: WHO Post COVID-19 identification
//! * `mlho`      — vignette 1: MSMR + logistic-regression workflow
//! * `bench`     — regenerate the paper's tables (table1|table2|enduser)
//! * `e2e`       — full pipeline: synth → mine → screen → MSMR → classify
//!
//! Run `tspm <command> --help` for options.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use tspm_plus::bench_util::experiments;
use tspm_plus::cli::{usage, Args, OptSpec};
use tspm_plus::config::RunConfig;
use tspm_plus::dbmart::{format_seq, DbMart, NumericDbMart};
use tspm_plus::engine::{BackendChoice, Engine, OutputChoice, SequenceOutput};
use tspm_plus::metrics::{fmt_bytes, PhaseTimer};
use tspm_plus::mining::MiningConfig;
use tspm_plus::postcovid::{self, PostCovidConfig};
use tspm_plus::runtime::ArtifactSet;
use tspm_plus::sparsity::{self, SparsityConfig};
use tspm_plus::synthea::{Scenario, SyntheaConfig, COVID_CODE, SYMPTOM_CODES};
use tspm_plus::{ml, seqstore};

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some((cmd, rest)) = argv.split_first() else {
        print_global_help();
        return ExitCode::from(2);
    };
    let result = match cmd.as_str() {
        "synth" => cmd_synth(rest),
        "mine" => cmd_mine(rest),
        "screen" => cmd_screen(rest),
        "postcovid" => cmd_postcovid(rest),
        "mlho" => cmd_mlho(rest),
        "bench" => cmd_bench(rest),
        "e2e" => cmd_e2e(rest),
        "--help" | "-h" | "help" => {
            print_global_help();
            Ok(())
        }
        other => Err(format!("unknown command {other:?}; try --help")),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn print_global_help() {
    println!(
        "tspm — transitive sequential pattern mining (tSPM+ reproduction)\n\n\
         commands:\n\
         \x20 synth      generate a synthetic clinical dbmart\n\
         \x20 mine       mine transitive sequences (+durations) from a dbmart CSV\n\
         \x20 screen     sparsity-screen a mined sequence file\n\
         \x20 postcovid  vignette 2: WHO Post COVID-19 identification\n\
         \x20 mlho       vignette 1: MSMR + classifier workflow\n\
         \x20 bench      regenerate paper tables (table1|table2|enduser)\n\
         \x20 e2e        full pipeline incl. PJRT artifacts\n\n\
         run `tspm <command> --help` for options"
    );
}

fn wants_help(argv: &[String]) -> bool {
    argv.iter().any(|a| a == "--help" || a == "-h")
}

// ---------------------------------------------------------------------------
// synth
// ---------------------------------------------------------------------------

fn cmd_synth(argv: &[String]) -> Result<(), String> {
    let spec = [
        OptSpec::value("patients", Some("1000"), "cohort size"),
        OptSpec::value("avg-entries", Some("318"), "mean entries per patient"),
        OptSpec::value("vocab", Some("5000"), "background code vocabulary"),
        OptSpec::value("seed", Some("7"), "RNG seed"),
        OptSpec::value("scenario", Some("covid"), "covid|generic"),
        OptSpec::value("out", Some("dbmart.csv"), "output CSV path"),
        OptSpec::value("truth-out", None, "write ground-truth JSON here"),
    ];
    if wants_help(argv) {
        print!("{}", usage("tspm synth", "generate a synthetic dbmart", &spec));
        return Ok(());
    }
    let a = Args::parse(argv, &spec).map_err(|e| e.to_string())?;
    let scenario = match a.get("scenario").unwrap() {
        "covid" => Scenario::Covid,
        "generic" => Scenario::Generic,
        other => return Err(format!("scenario must be covid|generic, got {other}")),
    };
    let cfg = SyntheaConfig {
        patients: a.req("patients").map_err(|e| e.to_string())?,
        avg_entries: a.req("avg-entries").map_err(|e| e.to_string())?,
        vocab_size: a.req("vocab").map_err(|e| e.to_string())?,
        seed: a.req("seed").map_err(|e| e.to_string())?,
        scenario,
        ..SyntheaConfig::synthea_covid_like(1.0)
    };
    let g = cfg.generate_with_truth();
    let out = PathBuf::from(a.get("out").unwrap());
    g.dbmart.write_csv(&out).map_err(|e| e.to_string())?;
    println!(
        "wrote {} rows for {} patients to {}",
        g.dbmart.len(),
        cfg.patients,
        out.display()
    );
    if let Some(truth_path) = a.get("truth-out") {
        use tspm_plus::json::Json;
        let truth = Json::obj(vec![
            (
                "postcovid",
                Json::Arr(
                    g.truth
                        .postcovid
                        .iter()
                        .map(|(p, s)| {
                            Json::Arr(vec![Json::from(p.clone()), Json::from(s.clone())])
                        })
                        .collect(),
                ),
            ),
            (
                "infected",
                Json::Arr(g.truth.infected.iter().map(|p| Json::from(p.clone())).collect()),
            ),
        ]);
        std::fs::write(truth_path, truth.to_string_pretty()).map_err(|e| e.to_string())?;
        println!("wrote ground truth to {truth_path}");
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// mine
// ---------------------------------------------------------------------------

fn load_numeric(input: &str) -> Result<NumericDbMart, String> {
    let raw = DbMart::read_csv(Path::new(input)).map_err(|e| e.to_string())?;
    NumericDbMart::try_encode(&raw).map_err(|e| e.to_string())
}

fn cmd_mine(argv: &[String]) -> Result<(), String> {
    let spec = [
        OptSpec::required("input", "dbmart CSV path"),
        OptSpec::value("out", Some("sequences.tspm"), "output sequence file"),
        OptSpec::value("lookup-out", Some("lookup.json"), "lookup-table JSON output"),
        OptSpec::value("backend", Some("auto"), "auto|memory|sharded|file|streaming"),
        OptSpec::value("mode", None, "deprecated alias for --backend (memory|file)"),
        OptSpec::value("threads", Some("0"), "worker threads (0 = auto)"),
        OptSpec::value("shards", Some("0"), "shards for the sharded backend (0 = auto)"),
        OptSpec::value("duration-unit", Some("1"), "duration unit in days"),
        OptSpec::value("sparsity", Some("0"), "min patients per sequence (0 = no screen)"),
        OptSpec::value("memory-budget-mb", Some("4096"), "budget steering the auto backend"),
        OptSpec::value(
            "out-dir",
            None,
            "leave the (screened) result as spill files here instead of \
             materialising one .tspm — the out-of-core path for results \
             larger than memory",
        ),
        OptSpec::flag("first-occurrence", "keep only first occurrence of each phenX"),
        OptSpec::flag("explain", "print a Fig.2-style decomposition of sample sequences"),
    ];
    if wants_help(argv) {
        print!("{}", usage("tspm mine", "mine transitive sequences", &spec));
        return Ok(());
    }
    let a = Args::parse(argv, &spec).map_err(|e| e.to_string())?;
    let mut timer = PhaseTimer::new();

    let db = timer.run("load+encode", || load_numeric(a.get("input").unwrap()))?;
    let threads: usize = a.req("threads").map_err(|e| e.to_string())?;
    let mut backend: BackendChoice = a.get("backend").unwrap().parse()?;
    // Legacy `--mode memory|file` keeps working as a backend alias
    // (an explicit non-auto --backend wins).
    if let Some(mode) = a.get("mode") {
        eprintln!("warning: --mode is deprecated; use --backend {mode}");
        if backend == BackendChoice::Auto {
            backend = match mode {
                "memory" => BackendChoice::InMemory,
                "file" => BackendChoice::FileBacked,
                other => return Err(format!("mode must be memory|file, got {other}")),
            };
        }
    }
    let budget_mb: u64 = a.req("memory-budget-mb").map_err(|e| e.to_string())?;
    let mining_cfg = MiningConfig {
        threads,
        first_occurrence_only: a.flag("first-occurrence"),
        duration_unit_days: a.req("duration-unit").map_err(|e| e.to_string())?,
        work_dir: std::env::temp_dir().join("tspm_mine"),
        shards: a.req("shards").map_err(|e| e.to_string())?,
        ..Default::default()
    };

    // Assemble the pipeline through the engine façade; the backend is
    // picked explicitly or auto-selected from the memory forecast.
    // `--out-dir` requests the out-of-core result contract; without it
    // the CLI keeps its historical single-file behaviour by pinning the
    // in-memory output.
    let out_dir = a.get("out-dir").map(PathBuf::from);
    let mut engine = Engine::from_dbmart(db)
        .backend(backend)
        .memory_budget(budget_mb << 20)
        .mine(mining_cfg);
    engine = match &out_dir {
        Some(dir) => engine.output(OutputChoice::Spilled).out_dir(dir.clone()),
        None => engine.output(OutputChoice::InMemory),
    };
    let min_patients: u32 = a.req("sparsity").map_err(|e| e.to_string())?;
    if min_patients > 0 {
        engine = engine.screen(SparsityConfig { min_patients, threads });
    }
    let result = timer.run("run", || engine.run()).map_err(|e| e.to_string())?;
    let db = result.db;

    if let Some(stats) = result.screen_stats {
        println!(
            "screen: {} → {} records ({} → {} distinct sequences)",
            stats.records_before, stats.records_after, stats.distinct_before, stats.distinct_after
        );
    }

    match result.sequences {
        SequenceOutput::Spilled(files) => {
            let dir = out_dir.expect("spilled output implies --out-dir");
            std::fs::write(
                dir.join("lookup.json"),
                db.lookup.to_json().to_string_pretty(),
            )
            .map_err(|e| e.to_string())?;
            if a.flag("explain") {
                eprintln!("note: --explain is skipped for spilled output");
            }
            println!(
                "mined {} sequences from {} patients ({} entries) → {} spill file(s) \
                 under {} ({}), lookup.json alongside",
                files.total_records,
                db.num_patients(),
                db.len(),
                files.files.len(),
                dir.display(),
                fmt_bytes(files.logical_bytes()),
            );
            for f in &files.files {
                println!("  {}", f.display());
            }
        }
        SequenceOutput::InMemory(set) => {
            let records = set.records;
            if a.flag("explain") {
                println!("\nFig.2-style decomposition (first 5 sequences):");
                for r in records.iter().take(5) {
                    let (s, e) = tspm_plus::dbmart::decode_seq(r.seq);
                    println!(
                        "  {:>16} = {:<24} [{} -> {}] duration {}d patient {}",
                        r.seq,
                        format_seq(r.seq),
                        db.lookup.phenx_name(s),
                        db.lookup.phenx_name(e),
                        r.duration,
                        db.lookup.patient_name(r.pid),
                    );
                }
                println!();
            }

            let out = PathBuf::from(a.get("out").unwrap());
            timer
                .run("write", || seqstore::write_file(&out, &records))
                .map_err(|e| e.to_string())?;
            std::fs::write(
                a.get("lookup-out").unwrap(),
                db.lookup.to_json().to_string_pretty(),
            )
            .map_err(|e| e.to_string())?;

            println!(
                "mined {} sequences from {} patients ({} entries) → {}",
                records.len(),
                db.num_patients(),
                db.len(),
                out.display()
            );
        }
    }
    print!("{}", result.report.render());
    print!("{}", timer.report());
    Ok(())
}

// ---------------------------------------------------------------------------
// screen
// ---------------------------------------------------------------------------

fn cmd_screen(argv: &[String]) -> Result<(), String> {
    let spec = [
        OptSpec::required("input", "mined sequence file (.tspm)"),
        OptSpec::value("out", Some("screened.tspm"), "output file"),
        OptSpec::value("min-patients", Some("50"), "distinct-patient threshold"),
        OptSpec::value("threads", Some("0"), "worker threads (0 = auto)"),
    ];
    if wants_help(argv) {
        print!("{}", usage("tspm screen", "sparsity-screen sequences", &spec));
        return Ok(());
    }
    let a = Args::parse(argv, &spec).map_err(|e| e.to_string())?;
    let mut records =
        seqstore::read_file(Path::new(a.get("input").unwrap())).map_err(|e| e.to_string())?;
    let stats = sparsity::screen(
        &mut records,
        &SparsityConfig {
            min_patients: a.req("min-patients").map_err(|e| e.to_string())?,
            threads: a.req("threads").map_err(|e| e.to_string())?,
        },
    );
    seqstore::write_file(Path::new(a.get("out").unwrap()), &records)
        .map_err(|e| e.to_string())?;
    println!(
        "screened {} → {} records ({} → {} distinct sequences) → {}",
        stats.records_before,
        stats.records_after,
        stats.distinct_before,
        stats.distinct_after,
        a.get("out").unwrap()
    );
    Ok(())
}

// ---------------------------------------------------------------------------
// postcovid (vignette 2)
// ---------------------------------------------------------------------------

fn cmd_postcovid(argv: &[String]) -> Result<(), String> {
    let spec = [
        OptSpec::value("patients", Some("500"), "synthetic cohort size"),
        OptSpec::value("seed", Some("11"), "RNG seed"),
        OptSpec::value("corr-threshold", Some("0.4"), "exclusion correlation threshold"),
        OptSpec::flag("use-artifacts", "run correlations on PJRT artifacts"),
    ];
    if wants_help(argv) {
        print!("{}", usage("tspm postcovid", "WHO Post COVID-19 vignette", &spec));
        return Ok(());
    }
    let a = Args::parse(argv, &spec).map_err(|e| e.to_string())?;
    let mut gen_cfg = SyntheaConfig::small();
    gen_cfg.patients = a.req("patients").map_err(|e| e.to_string())?;
    gen_cfg.seed = a.req("seed").map_err(|e| e.to_string())?;
    let g = gen_cfg.generate_with_truth();
    let run = Engine::from_raw(&g.dbmart)
        .map_err(|e| e.to_string())?
        .mine(MiningConfig::default())
        .run()
        .map_err(|e| e.to_string())?;
    let db = run.db;
    let mined = run.sequences.materialize().map_err(|e| e.to_string())?;

    let covid = db
        .lookup
        .phenx_id(COVID_CODE)
        .ok_or_else(|| "no covid code in cohort".to_string())?;
    let mut cfg = PostCovidConfig::new(covid);
    cfg.corr_threshold = a.req("corr-threshold").map_err(|e| e.to_string())?;
    cfg.candidate_filter =
        Some(SYMPTOM_CODES.iter().filter_map(|s| db.lookup.phenx_id(s)).collect());

    let artifacts = if a.flag("use-artifacts") {
        Some(ArtifactSet::load(&tspm_plus::runtime::default_artifacts_dir()).map_err(|e| e.to_string())?)
    } else {
        None
    };
    let result = postcovid::identify(
        &mined.records,
        db.num_patients() as u32,
        &cfg,
        artifacts.as_ref(),
    )
    .map_err(|e| e.to_string())?;

    println!(
        "candidates: {}   confirmed: {}   excluded: {}",
        result.candidates.len(),
        result.confirmed.len(),
        result.excluded.len()
    );
    for (pid, sym) in result.confirmed.iter().take(10) {
        println!(
            "  {} has Post-COVID symptom {}",
            db.lookup.patient_name(*pid),
            db.lookup.phenx_name(*sym)
        );
    }
    let v = postcovid::validate(&result, &g.truth, &db.lookup);
    println!(
        "vs ground truth: precision {:.3}  recall {:.3}  f1 {:.3}  (tp={} fp={} fn={})",
        v.precision(),
        v.recall(),
        v.f1(),
        v.true_positives,
        v.false_positives,
        v.false_negatives
    );
    Ok(())
}

// ---------------------------------------------------------------------------
// mlho (vignette 1)
// ---------------------------------------------------------------------------

fn cmd_mlho(argv: &[String]) -> Result<(), String> {
    let spec = [
        OptSpec::value("patients", Some("400"), "synthetic cohort size"),
        OptSpec::value("top-k", Some("200"), "MSMR features to keep"),
        OptSpec::value("epochs", Some("200"), "training epochs"),
        OptSpec::flag("use-artifacts", "run MI + training on PJRT artifacts"),
    ];
    if wants_help(argv) {
        print!("{}", usage("tspm mlho", "MSMR + classifier vignette", &spec));
        return Ok(());
    }
    let a = Args::parse(argv, &spec).map_err(|e| e.to_string())?;
    let artifacts = if a.flag("use-artifacts") {
        Some(ArtifactSet::load(&tspm_plus::runtime::default_artifacts_dir()).map_err(|e| e.to_string())?)
    } else {
        None
    };
    let report = ml::mlho_vignette(
        a.req("patients").map_err(|e| e.to_string())?,
        a.req("top-k").map_err(|e| e.to_string())?,
        a.req("epochs").map_err(|e| e.to_string())?,
        artifacts.as_ref(),
    )
    .map_err(|e| e.to_string())?;
    print!("{report}");
    Ok(())
}

// ---------------------------------------------------------------------------
// bench
// ---------------------------------------------------------------------------

fn cmd_bench(argv: &[String]) -> Result<(), String> {
    let spec = [
        OptSpec::value("scale", Some("0.1"), "workload scale vs the paper's"),
        OptSpec::value("iterations", Some("3"), "iterations per row (paper: 10)"),
        OptSpec::value("json-out", None, "write machine-readable rows here"),
    ];
    if wants_help(argv) || argv.is_empty() {
        println!("usage: tspm bench <table1|table2|enduser> [options]\n");
        print!("{}", usage("tspm bench", "regenerate paper tables", &spec));
        return Ok(());
    }
    let (which, rest) = argv.split_first().unwrap();
    let a = Args::parse(rest, &spec).map_err(|e| e.to_string())?;
    let scale: f64 = a.req("scale").map_err(|e| e.to_string())?;
    let iters: usize = a.req("iterations").map_err(|e| e.to_string())?;

    let (rows, report) = match which.as_str() {
        "table1" => {
            let rows = experiments::table1(scale, iters);
            let report = experiments::table1_report(&rows);
            (rows, report)
        }
        "table2" => {
            let (total, cap, chunks) = experiments::table2_overflow_demo(scale);
            let rows = experiments::table2(scale, iters);
            let mut report = format!(
                "overflow gate: {total} sequences vs cap {cap} → adaptive partitioning uses {chunks} chunks\n"
            );
            report.push_str(&tspm_plus::bench_util::render_table(
                "Table 2 — performance benchmark (tSPM+)",
                &rows,
            ));
            (rows, report)
        }
        "enduser" => {
            let rows = experiments::enduser(iters);
            let report = tspm_plus::bench_util::render_table(
                "End-user device benchmark (1k patients × ~400 entries)",
                &rows,
            );
            (rows, report)
        }
        other => return Err(format!("unknown bench {other:?} (table1|table2|enduser)")),
    };
    print!("{report}");
    if let Some(path) = a.get("json-out") {
        std::fs::write(path, tspm_plus::bench_util::rows_to_json(&rows).to_string_pretty())
            .map_err(|e| e.to_string())?;
        println!("wrote {path}");
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// e2e
// ---------------------------------------------------------------------------

fn cmd_e2e(argv: &[String]) -> Result<(), String> {
    let spec = [
        OptSpec::value("config", None, "RunConfig JSON path (defaults inline)"),
        OptSpec::value("patients", Some("500"), "cohort size when no config given"),
        OptSpec::flag("no-artifacts", "skip PJRT; use pure-Rust analytics"),
    ];
    if wants_help(argv) {
        print!("{}", usage("tspm e2e", "full end-to-end pipeline", &spec));
        return Ok(());
    }
    let a = Args::parse(argv, &spec).map_err(|e| e.to_string())?;
    let cfg = match a.get("config") {
        Some(p) => RunConfig::load(Path::new(p)).map_err(|e| e.to_string())?,
        None => RunConfig {
            patients: a.req("patients").map_err(|e| e.to_string())?,
            ..Default::default()
        },
    };
    let artifacts = if a.flag("no-artifacts") {
        None
    } else {
        match ArtifactSet::load(Path::new(&cfg.artifacts_dir)) {
            Ok(set) => Some(set),
            Err(e) => {
                eprintln!("warning: {e}; continuing with pure-Rust analytics");
                None
            }
        }
    };
    let report =
        ml::mlho_vignette(cfg.patients, 200, 150, artifacts.as_ref()).map_err(|e| e.to_string())?;
    print!("{report}");
    Ok(())
}
