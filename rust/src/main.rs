//! `tspm` — the tSPM+ launcher.
//!
//! Subcommands:
//!
//! * `synth`     — generate a synthetic clinical dbmart (CSV + truth)
//! * `mine`      — mine transitive sequences from a dbmart CSV
//! * `screen`    — sparsity-screen a mined sequence file
//! * `index`     — build a query-index artifact over a spilled run
//! * `ingest`    — mine a delta cohort into a new segment of a segment set
//! * `compact`   — fold a segment set into one artifact (bounded merge)
//! * `query`     — point/range queries against an index artifact or a
//!   segment set's merged view (JSON out)
//! * `serve`     — long-lived query daemon over one or more index artifacts
//! * `client`    — talk to a running daemon (also the serve e2e harness)
//! * `matrix`    — build the patient×sequence CSR straight from an index
//! * `postcovid` — vignette 2: WHO Post COVID-19 identification
//! * `mlho`      — vignette 1: MSMR + logistic-regression workflow
//! * `bench`     — regenerate the paper's tables (table1|table2|enduser)
//! * `e2e`       — full pipeline: synth → mine → screen → MSMR → classify
//!
//! Run `tspm <command> --help` for options.
//!
//! Exit codes: `0` success, `1` generic failure, `2` usage,
//! `3` index artifact failed to open (missing/garbled — the message
//! names the path), `4` a daemon answered `tspm client` with a typed
//! error frame (e.g. `not_found` after a hot-swap retire).

use std::path::{Path, PathBuf};
use std::process::ExitCode;
use std::sync::Arc;
use std::time::Duration;

use tspm_plus::bench_util::experiments;
use tspm_plus::cli::{usage, Args, OptSpec};
use tspm_plus::config::RunConfig;
use tspm_plus::dbmart::{format_seq, DbMart, LookupTables, NumericDbMart};
use tspm_plus::engine::{BackendChoice, Engine, OutputChoice, SequenceOutput};
use tspm_plus::ingest::{compact, CompactConfig, MergedView, SegmentSet};
use tspm_plus::json::Json;
use tspm_plus::metrics::{fmt_bytes, PhaseTimer};
use tspm_plus::mining::MiningConfig;
use tspm_plus::postcovid::{self, PostCovidConfig};
use tspm_plus::query::{self, IndexConfig, QuerySurface, DEFAULT_CACHE_BYTES};
use tspm_plus::runtime::ArtifactSet;
use tspm_plus::serve::{
    self, registry::open_service, Client, Registry, ServeConfig, ServeError, Server,
    WorkloadConfig,
};
use tspm_plus::sparsity::{self, SparsityConfig};
use tspm_plus::synthea::{Scenario, SyntheaConfig, COVID_CODE, SYMPTOM_CODES};
use tspm_plus::{ml, seqstore};

/// An index artifact failed to open: missing or garbled manifest, bad
/// data files. The error message names the offending path.
const EXIT_ARTIFACT: u8 = 3;
/// The daemon answered `tspm client` with a typed error frame.
const EXIT_REMOTE: u8 = 4;

/// A command failure with its process exit code. `From<String>` keeps
/// the plain-`String` error plumbing of the older subcommands working
/// (`?` converts to the generic code 1).
struct CmdError {
    code: u8,
    message: String,
}

impl From<String> for CmdError {
    fn from(message: String) -> Self {
        CmdError { code: 1, message }
    }
}

impl From<&str> for CmdError {
    fn from(message: &str) -> Self {
        CmdError { code: 1, message: message.to_string() }
    }
}

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some((cmd, rest)) = argv.split_first() else {
        print_global_help();
        return ExitCode::from(2);
    };
    let result: Result<(), CmdError> = match cmd.as_str() {
        "synth" => cmd_synth(rest).map_err(CmdError::from),
        "mine" => cmd_mine(rest).map_err(CmdError::from),
        "screen" => cmd_screen(rest).map_err(CmdError::from),
        "index" => cmd_index(rest).map_err(CmdError::from),
        "ingest" => cmd_ingest(rest).map_err(CmdError::from),
        "compact" => cmd_compact(rest),
        "query" => cmd_query(rest),
        "serve" => cmd_serve(rest),
        "client" => cmd_client(rest),
        "matrix" => cmd_matrix(rest).map_err(CmdError::from),
        "postcovid" => cmd_postcovid(rest).map_err(CmdError::from),
        "mlho" => cmd_mlho(rest).map_err(CmdError::from),
        "bench" => cmd_bench(rest).map_err(CmdError::from),
        "e2e" => cmd_e2e(rest).map_err(CmdError::from),
        "--help" | "-h" | "help" => {
            print_global_help();
            Ok(())
        }
        other => Err(CmdError::from(format!("unknown command {other:?}; try --help"))),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {}", e.message);
            ExitCode::from(e.code)
        }
    }
}

fn print_global_help() {
    println!(
        "tspm — transitive sequential pattern mining (tSPM+ reproduction)\n\n\
         commands:\n\
         \x20 synth      generate a synthetic clinical dbmart\n\
         \x20 mine       mine transitive sequences (+durations) from a dbmart CSV\n\
         \x20 screen     sparsity-screen a mined sequence file\n\
         \x20 index      build a query-index artifact over a spilled run\n\
         \x20 ingest     mine a delta cohort into a new segment of a segment set\n\
         \x20 compact    fold a segment set into one artifact (bounded merge)\n\
         \x20 query      point/range queries against an index or segment set (JSON output)\n\
         \x20 serve      long-lived query daemon over index artifacts\n\
         \x20 client     talk to a running daemon (queries, workload, admin)\n\
         \x20 matrix     patient×sequence CSR straight from an index (JSON output)\n\
         \x20 postcovid  vignette 2: WHO Post COVID-19 identification\n\
         \x20 mlho       vignette 1: MSMR + classifier workflow\n\
         \x20 bench      regenerate paper tables (table1|table2|enduser)\n\
         \x20 e2e        full pipeline incl. PJRT artifacts\n\n\
         run `tspm <command> --help` for options"
    );
}

fn wants_help(argv: &[String]) -> bool {
    argv.iter().any(|a| a == "--help" || a == "-h")
}

// ---------------------------------------------------------------------------
// synth
// ---------------------------------------------------------------------------

fn cmd_synth(argv: &[String]) -> Result<(), String> {
    let spec = [
        OptSpec::value("patients", Some("1000"), "cohort size"),
        OptSpec::value("avg-entries", Some("318"), "mean entries per patient"),
        OptSpec::value("vocab", Some("5000"), "background code vocabulary"),
        OptSpec::value("seed", Some("7"), "RNG seed"),
        OptSpec::value("scenario", Some("covid"), "covid|generic"),
        OptSpec::value("out", Some("dbmart.csv"), "output CSV path"),
        OptSpec::value("truth-out", None, "write ground-truth JSON here"),
    ];
    if wants_help(argv) {
        print!("{}", usage("tspm synth", "generate a synthetic dbmart", &spec));
        return Ok(());
    }
    let a = Args::parse(argv, &spec).map_err(|e| e.to_string())?;
    let scenario = match a.get("scenario").unwrap() {
        "covid" => Scenario::Covid,
        "generic" => Scenario::Generic,
        other => return Err(format!("scenario must be covid|generic, got {other}")),
    };
    let cfg = SyntheaConfig {
        patients: a.req("patients").map_err(|e| e.to_string())?,
        avg_entries: a.req("avg-entries").map_err(|e| e.to_string())?,
        vocab_size: a.req("vocab").map_err(|e| e.to_string())?,
        seed: a.req("seed").map_err(|e| e.to_string())?,
        scenario,
        ..SyntheaConfig::synthea_covid_like(1.0)
    };
    let g = cfg.generate_with_truth();
    let out = PathBuf::from(a.get("out").unwrap());
    g.dbmart.write_csv(&out).map_err(|e| e.to_string())?;
    println!(
        "wrote {} rows for {} patients to {}",
        g.dbmart.len(),
        cfg.patients,
        out.display()
    );
    if let Some(truth_path) = a.get("truth-out") {
        let truth = Json::obj(vec![
            (
                "postcovid",
                Json::Arr(
                    g.truth
                        .postcovid
                        .iter()
                        .map(|(p, s)| {
                            Json::Arr(vec![Json::from(p.clone()), Json::from(s.clone())])
                        })
                        .collect(),
                ),
            ),
            (
                "infected",
                Json::Arr(g.truth.infected.iter().map(|p| Json::from(p.clone())).collect()),
            ),
        ]);
        std::fs::write(truth_path, truth.to_string_pretty()).map_err(|e| e.to_string())?;
        println!("wrote ground truth to {truth_path}");
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// mine
// ---------------------------------------------------------------------------

fn load_numeric(input: &str) -> Result<NumericDbMart, String> {
    let raw = DbMart::read_csv(Path::new(input)).map_err(|e| e.to_string())?;
    NumericDbMart::try_encode(&raw).map_err(|e| e.to_string())
}

/// The four `--target-*` options shared by `mine` and `ingest`.
fn target_opt_specs() -> [OptSpec; 4] {
    [
        OptSpec::value(
            "target-code",
            None,
            "mine only pairs touching this code name (repeatable)",
        ),
        OptSpec::value(
            "target-pos",
            Some("either"),
            "which end a target code must occupy: first|second|either",
        ),
        OptSpec::value("target-dur-min", None, "inclusive min duration (encoded units)"),
        OptSpec::value("target-dur-max", None, "inclusive max duration (encoded units)"),
    ]
}

/// Build the [`tspm_plus::target::TargetSpec`] the `--target-*` flags
/// describe, resolving code names against `db`'s vocabulary. Funnels
/// through [`RunConfig::target_spec_with`] so the CLI, config files and
/// the engine validate targeting through one path.
fn target_from_args(
    a: &Args,
    db: &NumericDbMart,
) -> Result<Option<tspm_plus::target::TargetSpec>, String> {
    let mut cfg = RunConfig::default();
    cfg.target_codes = a.get_all("target-code").into_iter().map(str::to_string).collect();
    if let Some(p) = a.get("target-pos") {
        cfg.target_pos = p.to_string();
    }
    cfg.target_dur_min = a.get_parsed("target-dur-min").map_err(|e| e.to_string())?;
    cfg.target_dur_max = a.get_parsed("target-dur-max").map_err(|e| e.to_string())?;
    cfg.target_spec_with(|name| db.lookup.phenx_id(name))
}

fn cmd_mine(argv: &[String]) -> Result<(), String> {
    let mut spec = vec![
        OptSpec::required("input", "dbmart CSV path"),
        OptSpec::value("out", Some("sequences.tspm"), "output sequence file"),
        OptSpec::value("lookup-out", Some("lookup.json"), "lookup-table JSON output"),
        OptSpec::value("backend", Some("auto"), "auto|memory|sharded|file|streaming"),
        OptSpec::value("mode", None, "deprecated alias for --backend (memory|file)"),
        OptSpec::value("threads", Some("0"), "worker threads (0 = auto)"),
        OptSpec::value("shards", Some("0"), "shards for the sharded backend (0 = auto)"),
        OptSpec::value("duration-unit", Some("1"), "duration unit in days"),
        OptSpec::value("sparsity", Some("0"), "min patients per sequence (0 = no screen)"),
        OptSpec::value(
            "memory-budget-mb",
            Some("4096"),
            "budget steering the auto backend (env TSPM_MEMORY_BUDGET, in bytes, \
             overrides this default when the flag is not given)",
        ),
        OptSpec::value(
            "out-dir",
            None,
            "leave the (screened) result as spill files here instead of \
             materialising one .tspm — the out-of-core path for results \
             larger than memory",
        ),
        OptSpec::flag("first-occurrence", "keep only first occurrence of each phenX"),
        OptSpec::flag("explain", "print a Fig.2-style decomposition of sample sequences"),
    ];
    spec.extend(target_opt_specs());
    if wants_help(argv) {
        print!("{}", usage("tspm mine", "mine transitive sequences", &spec));
        return Ok(());
    }
    let a = Args::parse(argv, &spec).map_err(|e| e.to_string())?;
    let mut timer = PhaseTimer::new();

    let db = timer.run("load+encode", || load_numeric(a.get("input").unwrap()))?;
    let threads: usize = a.req("threads").map_err(|e| e.to_string())?;
    let mut backend: BackendChoice = a.get("backend").unwrap().parse()?;
    // Legacy `--mode memory|file` keeps working as a backend alias
    // (an explicit non-auto --backend wins).
    if let Some(mode) = a.get("mode") {
        eprintln!("warning: --mode is deprecated; use --backend {mode}");
        if backend == BackendChoice::Auto {
            backend = match mode {
                "memory" => BackendChoice::InMemory,
                "file" => BackendChoice::FileBacked,
                other => return Err(format!("mode must be memory|file, got {other}")),
            };
        }
    }
    let budget_mb: u64 = a.req("memory-budget-mb").map_err(|e| e.to_string())?;
    let mut budget_bytes = budget_mb << 20;
    // `TSPM_MEMORY_BUDGET` (bytes) — the same env the test harness
    // honors — overrides the default when the flag is not explicit, so
    // CI can pin the whole pipeline's budget in one place.
    if !a.provided("memory-budget-mb") {
        if let Some(b) = std::env::var("TSPM_MEMORY_BUDGET")
            .ok()
            .and_then(|v| v.parse::<u64>().ok())
        {
            budget_bytes = b;
        }
    }
    let mining_cfg = MiningConfig {
        threads,
        first_occurrence_only: a.flag("first-occurrence"),
        duration_unit_days: a.req("duration-unit").map_err(|e| e.to_string())?,
        work_dir: std::env::temp_dir().join("tspm_mine"),
        shards: a.req("shards").map_err(|e| e.to_string())?,
        ..Default::default()
    };

    // Assemble the pipeline through the engine façade; the backend is
    // picked explicitly or auto-selected from the memory forecast.
    // `--out-dir` requests the out-of-core result contract; without it
    // the CLI keeps its historical single-file behaviour by pinning the
    // in-memory output.
    let out_dir = a.get("out-dir").map(PathBuf::from);
    let target = target_from_args(&a, &db)?;
    let mut engine = Engine::from_dbmart(db)
        .backend(backend)
        .memory_budget(budget_bytes)
        .mine(mining_cfg);
    if let Some(spec) = target {
        engine = engine.target(spec);
    }
    engine = match &out_dir {
        Some(dir) => engine.output(OutputChoice::Spilled).out_dir(dir.clone()),
        None => engine.output(OutputChoice::InMemory),
    };
    let min_patients: u32 = a.req("sparsity").map_err(|e| e.to_string())?;
    if min_patients > 0 {
        engine = engine.screen(SparsityConfig { min_patients, threads });
    }
    let result = timer.run("run", || engine.run()).map_err(|e| e.to_string())?;
    let db = result.db;

    if let Some(stats) = result.screen_stats {
        println!(
            "screen: {} → {} records ({} → {} distinct sequences)",
            stats.records_before, stats.records_after, stats.distinct_before, stats.distinct_after
        );
    }

    match result.sequences {
        SequenceOutput::Spilled(files) => {
            let dir = out_dir.expect("spilled output implies --out-dir");
            std::fs::write(
                dir.join("lookup.json"),
                db.lookup.to_json().to_string_pretty(),
            )
            .map_err(|e| e.to_string())?;
            // The versioned manifest (counts + per-file checksums) lets
            // `tspm index` verify this run before building; sorted =
            // screened (screen_spilled writes global (seq,pid,duration)
            // order, raw mined spills do not).
            query::write_spill_manifest(&dir, &files, result.screen_stats.is_some())
                .map_err(|e| e.to_string())?;
            if a.flag("explain") {
                eprintln!("note: --explain is skipped for spilled output");
            }
            println!(
                "mined {} sequences from {} patients ({} entries) → {} spill file(s) \
                 under {} ({}), lookup.json + manifest.json alongside",
                files.total_records,
                db.num_patients(),
                db.len(),
                files.files.len(),
                dir.display(),
                fmt_bytes(files.logical_bytes()),
            );
            for f in &files.files {
                println!("  {}", f.display());
            }
        }
        SequenceOutput::InMemory(set) => {
            let records = set.records;
            if a.flag("explain") {
                println!("\nFig.2-style decomposition (first 5 sequences):");
                for r in records.iter().take(5) {
                    let (s, e) = tspm_plus::dbmart::decode_seq(r.seq);
                    println!(
                        "  {:>16} = {:<24} [{} -> {}] duration {}d patient {}",
                        r.seq,
                        format_seq(r.seq),
                        db.lookup.phenx_name(s),
                        db.lookup.phenx_name(e),
                        r.duration,
                        db.lookup.patient_name(r.pid),
                    );
                }
                println!();
            }

            let out = PathBuf::from(a.get("out").unwrap());
            timer
                .run("write", || seqstore::write_file(&out, &records))
                .map_err(|e| e.to_string())?;
            std::fs::write(
                a.get("lookup-out").unwrap(),
                db.lookup.to_json().to_string_pretty(),
            )
            .map_err(|e| e.to_string())?;

            println!(
                "mined {} sequences from {} patients ({} entries) → {}",
                records.len(),
                db.num_patients(),
                db.len(),
                out.display()
            );
        }
    }
    print!("{}", result.report.render());
    print!("{}", timer.report());
    Ok(())
}

// ---------------------------------------------------------------------------
// screen
// ---------------------------------------------------------------------------

fn cmd_screen(argv: &[String]) -> Result<(), String> {
    let spec = [
        OptSpec::required("input", "mined sequence file (.tspm)"),
        OptSpec::value("out", Some("screened.tspm"), "output file"),
        OptSpec::value("min-patients", Some("50"), "distinct-patient threshold"),
        OptSpec::value("threads", Some("0"), "worker threads (0 = auto)"),
    ];
    if wants_help(argv) {
        print!("{}", usage("tspm screen", "sparsity-screen sequences", &spec));
        return Ok(());
    }
    let a = Args::parse(argv, &spec).map_err(|e| e.to_string())?;
    let mut records =
        seqstore::read_file(Path::new(a.get("input").unwrap())).map_err(|e| e.to_string())?;
    let stats = sparsity::screen(
        &mut records,
        &SparsityConfig {
            min_patients: a.req("min-patients").map_err(|e| e.to_string())?,
            threads: a.req("threads").map_err(|e| e.to_string())?,
        },
    );
    seqstore::write_file(Path::new(a.get("out").unwrap()), &records)
        .map_err(|e| e.to_string())?;
    println!(
        "screened {} → {} records ({} → {} distinct sequences) → {}",
        stats.records_before,
        stats.records_after,
        stats.distinct_before,
        stats.distinct_after,
        a.get("out").unwrap()
    );
    Ok(())
}

// ---------------------------------------------------------------------------
// index
// ---------------------------------------------------------------------------

fn cmd_index(argv: &[String]) -> Result<(), String> {
    let spec = [
        OptSpec::required("in-dir", "spilled run directory (tspm mine --out-dir)"),
        OptSpec::required("out-dir", "directory for the index artifact"),
        OptSpec::value("block-size", Some("4096"), "records per index block"),
        OptSpec::flag("no-verify", "skip input checksum verification"),
        OptSpec::flag(
            "no-pid-index",
            "skip the pid-major secondary index (writes a v1 artifact: half \
             the disk, but `tspm query --pid` falls back to scanning)",
        ),
    ];
    if wants_help(argv) {
        print!(
            "{}",
            usage("tspm index", "build a query-index artifact over a spilled run", &spec)
        );
        return Ok(());
    }
    let a = Args::parse(argv, &spec).map_err(|e| e.to_string())?;
    let in_dir = PathBuf::from(a.get("in-dir").unwrap());
    let out_dir = PathBuf::from(a.get("out-dir").unwrap());
    let block_records: usize = a.req("block-size").map_err(|e| e.to_string())?;
    let mut timer = PhaseTimer::new();

    let manifest = query::read_spill_manifest(&in_dir).map_err(|e| {
        format!("{e}\nhint: the input of tspm index is a `tspm mine --out-dir` directory")
    })?;
    if !manifest.sorted {
        return Err(format!(
            "{}: the spilled run is not sorted — the index needs the *screened* \
             result; re-run `tspm mine --out-dir` with --sparsity > 0",
            in_dir.display()
        ));
    }
    // Verification is fused into the build's streaming pass
    // (build_verified) so the input is read once, not twice.
    let cfg = IndexConfig {
        block_records,
        pid_index: !a.flag("no-pid-index"),
        ..Default::default()
    };
    let built = timer
        .run("build", || {
            if a.flag("no-verify") {
                query::index::build(&manifest.files, &out_dir, &cfg, None)
            } else {
                query::index::build_verified(&manifest, &out_dir, &cfg, None)
            }
        })
        .map_err(|e| e.to_string())?;
    // Carry the lookup tables alongside so downstream consumers can
    // translate numeric ids without going back to the mine directory.
    let lookup = in_dir.join("lookup.json");
    if lookup.exists() {
        std::fs::copy(&lookup, out_dir.join("lookup.json")).map_err(|e| e.to_string())?;
    }
    println!(
        "indexed {} records / {} distinct sequences → {} (v{}, {} blocks of {} records, \
         {}{})",
        built.total_records,
        built.distinct_seqs(),
        out_dir.display(),
        built.version,
        built.blocks.len(),
        block_records,
        fmt_bytes(built.artifact_bytes),
        if built.pids.is_some() { ", pid-major index" } else { "" },
    );
    print!("{}", timer.report());
    Ok(())
}

// ---------------------------------------------------------------------------
// ingest / compact
// ---------------------------------------------------------------------------

fn cmd_ingest(argv: &[String]) -> Result<(), String> {
    let mut spec = vec![
        OptSpec::required("input", "delta dbmart CSV path"),
        OptSpec::required("set-dir", "segment-set directory (created on first ingest)"),
        OptSpec::value("block-size", Some("4096"), "records per index block of the segment"),
        OptSpec::value(
            "sparsity",
            Some("1"),
            "min patients per sequence *within the delta* (1 = keep everything; \
             per-segment thresholds > 1 are not equivalent to screening the union)",
        ),
        OptSpec::value("threads", Some("0"), "worker threads (0 = auto)"),
        OptSpec::value("duration-unit", Some("1"), "duration unit in days (match the base)"),
        OptSpec::value("memory-budget-mb", Some("4096"), "budget for the mine+screen run"),
    ];
    spec.extend(target_opt_specs());
    if wants_help(argv) {
        print!(
            "{}",
            usage(
                "tspm ingest",
                "mine a delta cohort into a new immutable segment of a segment set. \
                 Segments must hold disjoint patients; the set-level lookup.json keeps \
                 one id space across deltas (`tspm query --set-dir` reads the merged \
                 view, `tspm compact` folds the set back to one artifact)",
                &spec
            )
        );
        return Ok(());
    }
    let a = Args::parse(argv, &spec).map_err(|e| e.to_string())?;
    let set_dir = PathBuf::from(a.get("set-dir").unwrap());
    let block_records: usize = a.req("block-size").map_err(|e| e.to_string())?;
    let threads: usize = a.req("threads").map_err(|e| e.to_string())?;
    let min_patients: u32 = a.req("sparsity").map_err(|e| e.to_string())?;
    if min_patients == 0 {
        return Err("ingest needs --sparsity ≥ 1 (segments hold sorted, screened \
                    records; 1 keeps every sequence)"
            .into());
    }
    let budget_mb: u64 = a.req("memory-budget-mb").map_err(|e| e.to_string())?;
    let mut timer = PhaseTimer::new();

    // Encode the delta against the set's persisted vocabulary so every
    // segment shares one dense id space; first ingest starts it.
    let raw = timer
        .run("load", || DbMart::read_csv(Path::new(a.get("input").unwrap())))
        .map_err(|e| e.to_string())?;
    let lookup_path = set_dir.join("lookup.json");
    let base = if lookup_path.is_file() {
        let text = std::fs::read_to_string(&lookup_path).map_err(|e| e.to_string())?;
        let json = Json::parse(&text)
            .map_err(|e| format!("{}: {e}", lookup_path.display()))?;
        LookupTables::from_json(&json)
            .ok_or_else(|| format!("{}: not a lookup table", lookup_path.display()))?
    } else {
        LookupTables::default()
    };
    let db = timer
        .run("encode", || NumericDbMart::try_encode_with(&raw, &base))
        .map_err(|e| e.to_string())?;

    let duration_unit: u32 = a.req("duration-unit").map_err(|e| e.to_string())?;
    let target = target_from_args(&a, &db)?;
    let work = std::env::temp_dir().join(format!("tspm_ingest_{}", std::process::id()));
    let result = timer.run("run", || {
        let mut engine = Engine::from_dbmart(db)
            .memory_budget(budget_mb << 20)
            .mine(MiningConfig {
                threads,
                duration_unit_days: duration_unit,
                work_dir: work.join("mine"),
                ..Default::default()
            });
        if let Some(spec) = target {
            engine = engine.target(spec);
        }
        engine
            .screen(SparsityConfig { min_patients, threads })
            .out_dir(work.join("run"))
            .ingest_with(set_dir.clone(), block_records)
            .run()
    });
    let result = match result {
        Ok(r) => r,
        Err(e) => {
            let _ = std::fs::remove_dir_all(&work);
            return Err(e.to_string());
        }
    };
    let built = result.index.expect("ingest plan returns the committed segment");

    // Persist the union vocabulary atomically only after the segment
    // committed — a crash leaves the old lookup and the old manifest.
    let tmp = set_dir.join("lookup.json.tmp");
    std::fs::write(&tmp, result.db.lookup.to_json().to_string_pretty())
        .map_err(|e| e.to_string())?;
    std::fs::rename(&tmp, &lookup_path).map_err(|e| e.to_string())?;
    let _ = std::fs::remove_dir_all(&work);

    let set = SegmentSet::open(&set_dir).map_err(|e| e.to_string())?;
    println!(
        "ingested {} rows → segment {} ({} records, {} distinct sequences, {}); \
         set {} now holds {} segment(s); union vocabulary {} patients / {} phenX",
        raw.len(),
        built.dir.file_name().and_then(|s| s.to_str()).unwrap_or("?"),
        built.total_records,
        built.distinct_seqs(),
        fmt_bytes(built.artifact_bytes),
        set_dir.display(),
        set.len(),
        result.db.num_patients(),
        result.db.num_phenx(),
    );
    print!("{}", result.report.render());
    print!("{}", timer.report());
    Ok(())
}

fn cmd_compact(argv: &[String]) -> Result<(), CmdError> {
    let spec = [
        OptSpec::required("set-dir", "segment-set directory (tspm ingest --set-dir)"),
        OptSpec::value("block-size", Some("4096"), "records per index block of the output"),
        OptSpec::value("memory-budget-mb", Some("64"), "merge-buffer budget"),
    ];
    if wants_help(argv) {
        print!(
            "{}",
            usage(
                "tspm compact",
                "fold every segment of a set into one artifact in a bounded-memory \
                 merge (bit-identical to a fresh index of the union); the manifest \
                 swaps atomically, so a crash leaves the old segments live",
                &spec
            )
        );
        return Ok(());
    }
    let a = Args::parse(argv, &spec).map_err(|e| e.to_string())?;
    let set_dir = PathBuf::from(a.get("set-dir").unwrap());
    let budget_mb: usize = a.req("memory-budget-mb").map_err(|e| e.to_string())?;
    let mut timer = PhaseTimer::new();
    let mut set = SegmentSet::open(&set_dir)
        .map_err(|e| CmdError { code: EXIT_ARTIFACT, message: format!("{}: {e}", set_dir.display()) })?;
    let folded = set.len();
    let cfg = CompactConfig {
        block_records: a.req("block-size").map_err(|e| e.to_string())?,
        buffer_bytes: budget_mb << 20,
        ..Default::default()
    };
    let built = timer
        .run("compact", || compact(&mut set, &cfg, None))
        .map_err(|e| e.to_string())?;
    println!(
        "compacted {} segment(s) → {} ({} records, {} distinct sequences, {} blocks, {})",
        folded,
        built.dir.display(),
        built.total_records,
        built.distinct_seqs(),
        built.blocks.len(),
        fmt_bytes(built.artifact_bytes),
    );
    print!("{}", timer.report());
    Ok(())
}

// ---------------------------------------------------------------------------
// matrix
// ---------------------------------------------------------------------------

fn cmd_matrix(argv: &[String]) -> Result<(), String> {
    let spec = [
        OptSpec::required("index-dir", "index artifact directory (tspm index --out-dir)"),
        OptSpec::value(
            "duration-bucket",
            None,
            "bucket days for the duration-aware column space (omit = plain binary)",
        ),
        OptSpec::value(
            "csr-out",
            None,
            "write the full CSR (seq_ids/row_ptr/col_idx) as JSON here",
        ),
    ];
    if wants_help(argv) {
        print!(
            "{}",
            usage(
                "tspm matrix",
                "build the patient×sequence CSR straight from an index artifact \
                 (streaming, never materialises the records; JSON summary to stdout)",
                &spec
            )
        );
        return Ok(());
    }
    let a = Args::parse(argv, &spec).map_err(|e| e.to_string())?;
    let idx = tspm_plus::query::SeqIndex::open(&PathBuf::from(a.get("index-dir").unwrap()))
        .map_err(|e| e.to_string())?;
    let bucket: Option<u32> = a.get_parsed("duration-bucket").map_err(|e| e.to_string())?;
    let num_patients = idx.num_patients;
    let t = std::time::Instant::now();
    let m = tspm_plus::matrix::SeqMatrix::from_index_tracked(&idx, num_patients, bucket, None)
        .map_err(|e| e.to_string())?;
    let build_ms = t.elapsed().as_secs_f64() * 1e3;
    if let Some(path) = a.get("csr-out") {
        let csr = Json::obj(vec![
            ("seq_ids", Json::Arr(m.seq_ids.iter().map(|&s| Json::from(s)).collect())),
            ("row_ptr", Json::Arr(m.row_ptr.iter().map(|&p| Json::from(p)).collect())),
            ("col_idx", Json::Arr(m.col_idx.iter().map(|&c| Json::from(c as u64)).collect())),
        ]);
        std::fs::write(path, csr.to_string_pretty()).map_err(|e| e.to_string())?;
    }
    let out = Json::obj(vec![
        ("command", Json::from("matrix")),
        ("index_records", Json::from(idx.total_records)),
        ("num_patients", Json::from(num_patients as u64)),
        ("num_cols", Json::from(m.num_cols())),
        ("nnz", Json::from(m.nnz())),
        (
            "duration_bucket_days",
            match bucket {
                Some(b) => Json::from(b as u64),
                None => Json::Null,
            },
        ),
        ("build_ms", Json::from(build_ms)),
    ]);
    print!("{}", out.to_string_pretty());
    Ok(())
}

// ---------------------------------------------------------------------------
// query
// ---------------------------------------------------------------------------

/// One parsed `tspm query` request (exactly one primary selector).
struct QuerySpec {
    seq: Option<u64>,
    pid: Option<u32>,
    top_k: Option<usize>,
    histogram: Option<usize>,
    dur_min: Option<u32>,
    dur_max: Option<u32>,
    limit: usize,
}

fn cmd_query(argv: &[String]) -> Result<(), CmdError> {
    let spec = [
        OptSpec::value("index-dir", None, "index artifact directory (tspm index --out-dir)"),
        OptSpec::value(
            "set-dir",
            None,
            "segment-set directory (tspm ingest --set-dir) — query the merged view \
             over every segment; alternative to --index-dir",
        ),
        OptSpec::value("seq", None, "sequence id — return its records"),
        OptSpec::value("pid", None, "patient id — return all of the patient's records"),
        OptSpec::value("top-k", None, "return the k sequences with the most distinct patients"),
        OptSpec::value("histogram", None, "with --seq: duration histogram with this many buckets"),
        OptSpec::value("duration-min", None, "with --seq: only durations ≥ this (patients_with)"),
        OptSpec::value("duration-max", None, "with --seq: only durations ≤ this (patients_with)"),
        OptSpec::value("limit", Some("1000"), "truncate record/patient lists to this many entries"),
        OptSpec::value("repeat", Some("1"), "run the query this many times (exercises the cache)"),
        OptSpec::flag("stats", "include cache statistics in the JSON output"),
    ];
    if wants_help(argv) {
        print!("{}", usage("tspm query", "query an index artifact (JSON to stdout)", &spec));
        return Ok(());
    }
    let a = Args::parse(argv, &spec).map_err(|e| e.to_string())?;
    let q = QuerySpec {
        seq: a.get_parsed("seq").map_err(|e| e.to_string())?,
        pid: a.get_parsed("pid").map_err(|e| e.to_string())?,
        top_k: a.get_parsed("top-k").map_err(|e| e.to_string())?,
        histogram: a.get_parsed("histogram").map_err(|e| e.to_string())?,
        dur_min: a.get_parsed("duration-min").map_err(|e| e.to_string())?,
        dur_max: a.get_parsed("duration-max").map_err(|e| e.to_string())?,
        limit: a.req("limit").map_err(|e| e.to_string())?,
    };
    let selectors =
        [q.seq.is_some(), q.pid.is_some(), q.top_k.is_some()].iter().filter(|&&s| s).count();
    if selectors != 1 {
        return Err("pick exactly one of --seq, --pid, --top-k".into());
    }
    if (q.histogram.is_some() || q.dur_min.is_some() || q.dur_max.is_some()) && q.seq.is_none() {
        return Err("--histogram and --duration-min/--duration-max need --seq".into());
    }
    if q.histogram.is_some() && (q.dur_min.is_some() || q.dur_max.is_some()) {
        return Err("--histogram and --duration-min/--duration-max are mutually exclusive".into());
    }
    let repeat: usize = a.req("repeat").map_err(|e| e.to_string())?;
    let repeat = repeat.max(1);

    // A missing/garbled artifact is a *distinct* failure class (exit
    // code 3, message naming the path) so orchestration — and serve's
    // registry, which shares open_service — can tell "bad artifact"
    // apart from "bad query". Both sources answer through the same
    // QuerySurface, so the query shapes below never notice which one
    // they run against.
    let svc: Box<dyn QuerySurface> = match (a.get("index-dir"), a.get("set-dir")) {
        (Some(dir), None) => Box::new(
            open_service(&PathBuf::from(dir), DEFAULT_CACHE_BYTES)
                .map_err(|e| CmdError { code: EXIT_ARTIFACT, message: e.to_string() })?,
        ),
        (None, Some(dir)) => Box::new(
            MergedView::open(&PathBuf::from(dir), DEFAULT_CACHE_BYTES).map_err(|e| {
                CmdError { code: EXIT_ARTIFACT, message: format!("{dir}: {e}") }
            })?,
        ),
        _ => return Err("pick exactly one of --index-dir, --set-dir".into()),
    };
    let mut latencies: Vec<f64> = Vec::with_capacity(repeat);
    let mut body = Json::Null;
    for _ in 0..repeat {
        let t = std::time::Instant::now();
        body = run_query(svc.as_ref(), &q)?;
        latencies.push(t.elapsed().as_secs_f64() * 1e3);
    }
    let Json::Obj(mut obj) = body else { unreachable!("run_query returns objects") };
    obj.insert(
        "latency_ms".to_string(),
        Json::Arr(latencies.iter().map(|&l| Json::from(l)).collect()),
    );
    if a.flag("stats") {
        let st = svc.stats();
        obj.insert(
            "stats".to_string(),
            Json::obj(vec![
                ("hits", Json::from(st.hits)),
                ("misses", Json::from(st.misses)),
                ("evictions", Json::from(st.evictions)),
                ("cached_entries", Json::from(st.cached_entries)),
                ("cached_bytes", Json::from(st.cached_bytes)),
                ("logical_bytes_read", Json::from(st.logical_bytes_read)),
            ]),
        );
    }
    print!("{}", Json::Obj(obj).to_string_pretty());
    Ok(())
}

fn run_query(svc: &dyn QuerySurface, q: &QuerySpec) -> Result<Json, String> {
    if let Some(k) = q.top_k {
        let got = svc.top_k_by_support(k).map_err(|e| e.to_string())?;
        return Ok(Json::obj(vec![
            ("query", Json::from("top_k")),
            ("k", Json::from(k)),
            (
                "sequences",
                Json::Arr(
                    got.iter()
                        .map(|s| {
                            Json::obj(vec![
                                ("seq", Json::from(s.seq)),
                                ("patients", Json::from(s.patients as u64)),
                                ("records", Json::from(s.records)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]));
    }
    if let Some(p) = q.pid {
        let got = svc.by_patient(p).map_err(|e| e.to_string())?;
        return Ok(Json::obj(vec![
            ("query", Json::from("by_patient")),
            ("pid", Json::from(p as u64)),
            ("count", Json::from(got.len())),
            ("returned", Json::from(got.len().min(q.limit))),
            (
                "records",
                Json::Arr(
                    got.iter()
                        .take(q.limit)
                        .map(|r| {
                            Json::obj(vec![
                                ("seq", Json::from(r.seq)),
                                ("duration", Json::from(r.duration as u64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]));
    }
    let s = q.seq.expect("validated: a selector is present");
    if let Some(n) = q.histogram {
        let h = svc.duration_histogram(s, n).map_err(|e| e.to_string())?;
        return Ok(Json::obj(vec![
            ("query", Json::from("duration_histogram")),
            ("seq", Json::from(s)),
            ("duration_min", Json::from(h.dur_min as u64)),
            ("duration_max", Json::from(h.dur_max as u64)),
            ("count", Json::from(h.total)),
            (
                "buckets",
                Json::Arr(
                    h.buckets
                        .iter()
                        .map(|b| {
                            Json::obj(vec![
                                ("lo", Json::from(b.lo as u64)),
                                ("hi", Json::from(b.hi as u64)),
                                ("count", Json::from(b.count)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]));
    }
    if q.dur_min.is_some() || q.dur_max.is_some() {
        let lo = q.dur_min.unwrap_or(0);
        let hi = q.dur_max.unwrap_or(u32::MAX);
        let got = svc.patients_with(s, lo, hi).map_err(|e| e.to_string())?;
        return Ok(Json::obj(vec![
            ("query", Json::from("patients_with")),
            ("seq", Json::from(s)),
            ("duration_min", Json::from(lo as u64)),
            ("duration_max", Json::from(hi as u64)),
            ("count", Json::from(got.len())),
            ("returned", Json::from(got.len().min(q.limit))),
            (
                "patients",
                Json::Arr(got.iter().take(q.limit).map(|&p| Json::from(p as u64)).collect()),
            ),
        ]));
    }
    let got = svc.by_sequence(s).map_err(|e| e.to_string())?;
    Ok(Json::obj(vec![
        ("query", Json::from("by_sequence")),
        ("seq", Json::from(s)),
        ("count", Json::from(got.len())),
        ("returned", Json::from(got.len().min(q.limit))),
        (
            "records",
            Json::Arr(
                got.iter()
                    .take(q.limit)
                    .map(|r| {
                        Json::obj(vec![
                            ("pid", Json::from(r.pid as u64)),
                            ("duration", Json::from(r.duration as u64)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]))
}

// ---------------------------------------------------------------------------
// serve
// ---------------------------------------------------------------------------

fn cmd_serve(argv: &[String]) -> Result<(), CmdError> {
    let spec = [
        OptSpec::value(
            "index-dir",
            None,
            "index artifact directory; repeatable (--index-dir a --index-dir b), \
             artifact id = directory name",
        ),
        OptSpec::value(
            "set-dir",
            None,
            "segment-set directory, served as ONE merged artifact (id = directory \
             name); repeatable and mixable with --index-dir",
        ),
        OptSpec::value("addr", Some("127.0.0.1:7878"), "listen address (host:port)"),
        OptSpec::value("max-conns", Some("64"), "connections before shedding with busy"),
        OptSpec::value("cache-mb", Some("8"), "per-artifact result cache (MiB)"),
        OptSpec::value("idle-timeout-secs", Some("30"), "close idle connections after this"),
        OptSpec::value(
            "metrics-addr",
            None,
            "also serve Prometheus text metrics over plain HTTP here \
             (e.g. 127.0.0.1:9187)",
        ),
        OptSpec::value(
            "slow-query-ms",
            None,
            "trace requests slower than this even when TSPM_TRACE is off",
        ),
    ];
    if wants_help(argv) {
        print!("{}", usage("tspm serve", "serve index artifacts over TCP", &spec));
        return Ok(());
    }
    let a = Args::parse(argv, &spec).map_err(|e| e.to_string())?;
    let cache_mb: usize = a.req("cache-mb").map_err(|e| e.to_string())?;
    let cache_bytes = cache_mb << 20;
    let registry = Arc::new(Registry::new(cache_bytes));
    if a.get_all("index-dir").is_empty() && a.get_all("set-dir").is_empty() {
        return Err("need at least one --index-dir or --set-dir".into());
    }
    let display_id = |path: &Path| {
        path.file_name()
            .and_then(|s| s.to_str())
            .filter(|s| !s.is_empty())
            .unwrap_or("index")
            .to_string()
    };
    for dir in a.get_all("index-dir") {
        let path = PathBuf::from(dir);
        let id = display_id(&path);
        // Same failure class and exit code as `tspm query` on a bad
        // artifact: code 3, message naming the path.
        let svc = open_service(&path, cache_bytes)
            .map_err(|e| CmdError { code: EXIT_ARTIFACT, message: e.to_string() })?;
        registry.register(&id, Arc::new(svc)).map_err(|e| e.to_string())?;
        eprintln!("registered artifact {id:?} from {}", path.display());
    }
    for dir in a.get_all("set-dir") {
        let path = PathBuf::from(dir);
        let id = display_id(&path);
        registry
            .open_and_register_set(&id, &path)
            .map_err(|e| CmdError { code: EXIT_ARTIFACT, message: e.to_string() })?;
        eprintln!("registered segment set {id:?} from {}", path.display());
    }
    let cfg = ServeConfig {
        max_conns: a.req("max-conns").map_err(|e| e.to_string())?,
        idle_timeout: Duration::from_secs(
            a.req("idle-timeout-secs").map_err(|e| e.to_string())?,
        ),
        slow_query_threshold: a
            .get_parsed::<u64>("slow-query-ms")
            .map_err(|e| e.to_string())?
            .map(Duration::from_millis),
        ..ServeConfig::default()
    };
    // The process-RSS collector samples /proc (or getrusage) at scrape
    // time; unavailable probes simply omit their lines.
    tspm_plus::obs::metrics::global().register_collector(Box::new(|out| {
        use tspm_plus::obs::metrics::{Sample, SampleKind};
        if let Some(peak) = tspm_plus::metrics::peak_rss_bytes() {
            out.push(Sample {
                name: tspm_plus::obs::names::PROCESS_PEAK_RSS_BYTES.to_string(),
                kind: SampleKind::Gauge,
                value: peak,
            });
        }
        if let Some(cur) = tspm_plus::metrics::current_rss_bytes() {
            out.push(Sample {
                name: tspm_plus::obs::names::PROCESS_CURRENT_RSS_BYTES.to_string(),
                kind: SampleKind::Gauge,
                value: cur,
            });
        }
    }));
    let metrics_server = match a.get("metrics-addr") {
        Some(maddr) => {
            let srv = tspm_plus::obs::expo::MetricsServer::bind(
                maddr,
                tspm_plus::obs::metrics::global(),
            )
            .map_err(|e| format!("cannot bind metrics endpoint {maddr}: {e}"))?;
            eprintln!("metrics endpoint on http://{}/metrics", srv.local_addr());
            Some(srv)
        }
        None => None,
    };
    let n_artifacts = registry.len();
    let server =
        Server::bind(a.get("addr").unwrap(), registry, cfg.clone()).map_err(|e| e.to_string())?;
    println!(
        "listening on {} ({} artifact(s), max {} connections)",
        server.local_addr(),
        n_artifacts,
        cfg.max_conns
    );
    // Make the banner visible immediately even when stdout is piped —
    // the e2e harness polls for it.
    use std::io::Write as _;
    let _ = std::io::stdout().flush();
    let summary = server.run().map_err(|e| e.to_string())?;
    if let Some(mut srv) = metrics_server {
        srv.shutdown();
    }
    println!(
        "drained: {} connection(s) served, {} shed, {} request(s) answered",
        summary.served, summary.shed, summary.requests
    );
    Ok(())
}

// ---------------------------------------------------------------------------
// client
// ---------------------------------------------------------------------------

fn cmd_client(argv: &[String]) -> Result<(), CmdError> {
    let spec = [
        OptSpec::value("addr", Some("127.0.0.1:7878"), "daemon address (host:port)"),
        OptSpec::value("artifact", None, "artifact id (omit when one is registered)"),
        OptSpec::flag("ping", "liveness check"),
        OptSpec::flag("list", "enumerate registered artifacts"),
        OptSpec::flag("stats", "cache/IO counters of one artifact"),
        OptSpec::value("seq", None, "by_sequence query"),
        OptSpec::value("pid", None, "by_patient query (streamed from the daemon)"),
        OptSpec::value("top-k", None, "k sequences with the most distinct patients"),
        OptSpec::value("histogram", None, "with --seq: duration histogram bucket count"),
        OptSpec::value("duration-min", None, "with --seq: patients_with lower bound"),
        OptSpec::value("duration-max", None, "with --seq: patients_with upper bound"),
        OptSpec::value("limit", Some("1000"), "truncate record/patient lists"),
        OptSpec::value("workload", None, "run a mixed benchmark workload of N requests"),
        OptSpec::value("workload-concurrency", Some("4"), "workload client connections"),
        OptSpec::value("workload-seed", Some("42"), "workload mix seed"),
        OptSpec::value("json-out", None, "also write the output JSON here"),
        OptSpec::value("register", None, "hot-add: register this index dir (needs --id)"),
        OptSpec::value("id", None, "artifact id for --register"),
        OptSpec::value("retire", None, "hot-swap: retire this artifact id"),
        OptSpec::flag("shutdown", "gracefully drain and stop the daemon"),
        OptSpec::flag("metrics", "print the daemon's Prometheus metrics text"),
        OptSpec::value(
            "trace-id",
            None,
            "hex trace id (1-32 chars) stamped on every request and adopted \
             by the daemon's server-side spans",
        ),
    ];
    if wants_help(argv) {
        print!("{}", usage("tspm client", "talk to a running tspm serve daemon", &spec));
        return Ok(());
    }
    let a = Args::parse(argv, &spec).map_err(|e| e.to_string())?;
    let addr = a.get("addr").unwrap().to_string();
    let artifact = a.get("artifact").map(str::to_string);

    // Exactly one action per invocation.
    let actions = [
        a.flag("ping"),
        a.flag("list"),
        a.flag("stats"),
        a.provided("seq"),
        a.provided("pid"),
        a.provided("top-k"),
        a.provided("workload"),
        a.provided("register"),
        a.provided("retire"),
        a.flag("shutdown"),
        a.flag("metrics"),
    ];
    if actions.iter().filter(|&&x| x).count() != 1 {
        return Err("pick exactly one action: --ping | --list | --stats | --seq | --pid | \
                    --top-k | --workload | --register | --retire | --shutdown | --metrics"
            .into());
    }

    // The workload drives its own connection pool.
    if a.provided("workload") {
        let wl = WorkloadConfig {
            requests: a.req("workload").map_err(|e| e.to_string())?,
            concurrency: a.req("workload-concurrency").map_err(|e| e.to_string())?,
            seed: a.req("workload-seed").map_err(|e| e.to_string())?,
            artifact,
        };
        let report = serve::client::run_mixed_workload(&addr, &wl).map_err(client_err)?;
        return emit(report.to_json(), a.get("json-out"));
    }

    let mut client = Client::connect(&addr).map_err(client_err)?;
    if let Some(hex) = a.get("trace-id") {
        let tid = tspm_plus::obs::TraceId::from_hex(hex)
            .ok_or_else(|| format!("--trace-id {hex:?} is not 1-32 hex characters"))?;
        client.set_trace_id(tid);
    }
    if a.flag("metrics") {
        // Raw exposition text, not JSON — pipe it straight to a file or
        // a promtool check.
        let text = client.metrics().map_err(client_err)?;
        print!("{text}");
        if let Some(path) = a.get("json-out") {
            std::fs::write(path, &text).map_err(|e| e.to_string())?;
        }
        return Ok(());
    }
    let out = run_client_action(&mut client, &a, artifact.as_deref());
    match out {
        Ok(json) => emit(json, a.get("json-out")),
        Err(ServeError::Remote { code, message }) => {
            // Surface the typed error as JSON on stdout (so harnesses can
            // assert on the code) AND as a distinct exit code.
            let j = Json::obj(vec![(
                "error",
                Json::obj(vec![
                    ("code", Json::from(code.as_str())),
                    ("message", Json::from(message.clone())),
                ]),
            )]);
            print!("{}", j.to_string_pretty());
            Err(CmdError { code: EXIT_REMOTE, message: format!("server error [{code}]: {message}") })
        }
        Err(e) => Err(client_err(e)),
    }
}

/// Non-remote client failures keep the generic exit code; typed remote
/// answers (including `busy` shedding) exit with [`EXIT_REMOTE`].
fn client_err(e: ServeError) -> CmdError {
    let code = match &e {
        ServeError::Remote { .. } | ServeError::Busy => EXIT_REMOTE,
        _ => 1,
    };
    CmdError { code, message: e.to_string() }
}

fn emit(json: Json, json_out: Option<&str>) -> Result<(), CmdError> {
    let text = json.to_string_pretty();
    print!("{text}");
    if let Some(path) = json_out {
        std::fs::write(path, &text).map_err(|e| e.to_string())?;
    }
    Ok(())
}

fn run_client_action(
    client: &mut Client,
    a: &Args,
    artifact: Option<&str>,
) -> Result<Json, ServeError> {
    let limit: usize = a.get_parsed("limit").map_err(|e| ServeError::Protocol(e.to_string()))?
        .unwrap_or(1000);
    let parse_u64 = |name: &str| -> Result<u64, ServeError> {
        a.get_parsed::<u64>(name)
            .map_err(|e| ServeError::Protocol(e.to_string()))
            .map(|v| v.expect("provided() checked"))
    };
    if a.flag("ping") {
        client.ping()?;
        return Ok(Json::obj(vec![("ok", Json::Bool(true))]));
    }
    if a.flag("list") {
        let arts = client.list()?;
        return Ok(Json::obj(vec![(
            "artifacts",
            Json::Arr(
                arts.iter()
                    .map(|x| {
                        let mut fields = vec![
                            ("id", Json::from(x.id.clone())),
                            ("records", Json::from(x.records)),
                            ("sequences", Json::from(x.sequences)),
                            ("patients", Json::from(x.patients as u64)),
                            ("version", Json::from(x.version)),
                        ];
                        if let Some(t) = &x.target {
                            fields.push(("target", Json::from(t.clone())));
                        }
                        Json::obj(fields)
                    })
                    .collect(),
            ),
        )]));
    }
    if a.flag("stats") {
        let (id, st) = client.stats(artifact)?;
        return Ok(Json::obj(vec![
            ("artifact", Json::from(id)),
            ("hits", Json::from(st.hits)),
            ("misses", Json::from(st.misses)),
            ("evictions", Json::from(st.evictions)),
            ("cached_entries", Json::from(st.cached_entries)),
            ("cached_bytes", Json::from(st.cached_bytes)),
            ("logical_bytes_read", Json::from(st.logical_bytes_read)),
        ]));
    }
    if a.provided("pid") {
        let pid = parse_u64("pid")? as u32;
        // Stream: count everything, keep only `limit` records resident.
        let mut kept: Vec<tspm_plus::mining::SeqRecord> = Vec::new();
        let total = client.by_patient_visit(artifact, pid, |chunk| {
            let room = limit.saturating_sub(kept.len());
            kept.extend_from_slice(&chunk[..chunk.len().min(room)]);
        })?;
        return Ok(Json::obj(vec![
            ("query", Json::from("by_patient")),
            ("pid", Json::from(pid as u64)),
            ("count", Json::from(total)),
            ("returned", Json::from(kept.len())),
            (
                "records",
                Json::Arr(
                    kept.iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("seq", Json::from(r.seq)),
                                ("duration", Json::from(r.duration as u64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]));
    }
    if a.provided("top-k") {
        let k = parse_u64("top-k")? as usize;
        let rows = client.top_k(artifact, k)?;
        return Ok(Json::obj(vec![
            ("query", Json::from("top_k")),
            ("k", Json::from(k)),
            (
                "sequences",
                Json::Arr(
                    rows.iter()
                        .map(|s| {
                            Json::obj(vec![
                                ("seq", Json::from(s.seq)),
                                ("patients", Json::from(s.patients as u64)),
                                ("records", Json::from(s.records)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]));
    }
    if let Some(dir) = a.get("register") {
        let id = a
            .get("id")
            .ok_or_else(|| ServeError::Protocol("--register needs --id".into()))?;
        client.register(id, dir)?;
        return Ok(Json::obj(vec![("ok", Json::Bool(true)), ("registered", Json::from(id))]));
    }
    if let Some(id) = a.get("retire") {
        client.retire(id)?;
        return Ok(Json::obj(vec![("ok", Json::Bool(true)), ("retired", Json::from(id))]));
    }
    if a.flag("shutdown") {
        client.shutdown()?;
        return Ok(Json::obj(vec![("ok", Json::Bool(true)), ("shutdown", Json::Bool(true))]));
    }
    // Remaining selector: --seq, optionally refined by --histogram or a
    // duration range (same shapes as `tspm query`).
    let seq = parse_u64("seq")?;
    if a.provided("histogram") {
        let buckets = parse_u64("histogram")? as usize;
        let h = client.histogram(artifact, seq, buckets)?;
        return Ok(Json::obj(vec![
            ("query", Json::from("duration_histogram")),
            ("seq", Json::from(seq)),
            ("duration_min", Json::from(h.dur_min as u64)),
            ("duration_max", Json::from(h.dur_max as u64)),
            ("count", Json::from(h.total)),
            (
                "buckets",
                Json::Arr(
                    h.buckets
                        .iter()
                        .map(|b| {
                            Json::obj(vec![
                                ("lo", Json::from(b.lo as u64)),
                                ("hi", Json::from(b.hi as u64)),
                                ("count", Json::from(b.count)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]));
    }
    if a.provided("duration-min") || a.provided("duration-max") {
        let lo = a
            .get_parsed::<u32>("duration-min")
            .map_err(|e| ServeError::Protocol(e.to_string()))?
            .unwrap_or(0);
        let hi = a
            .get_parsed::<u32>("duration-max")
            .map_err(|e| ServeError::Protocol(e.to_string()))?
            .unwrap_or(u32::MAX);
        let (pids, total) = client.patients_with(artifact, seq, lo, hi, Some(limit))?;
        return Ok(Json::obj(vec![
            ("query", Json::from("patients_with")),
            ("seq", Json::from(seq)),
            ("duration_min", Json::from(lo as u64)),
            ("duration_max", Json::from(hi as u64)),
            ("count", Json::from(total)),
            ("returned", Json::from(pids.len())),
            ("patients", Json::Arr(pids.iter().map(|&p| Json::from(p as u64)).collect())),
        ]));
    }
    let (records, total) = client.by_sequence(artifact, seq, Some(limit))?;
    Ok(Json::obj(vec![
        ("query", Json::from("by_sequence")),
        ("seq", Json::from(seq)),
        ("count", Json::from(total)),
        ("returned", Json::from(records.len())),
        (
            "records",
            Json::Arr(
                records
                    .iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("pid", Json::from(r.pid as u64)),
                            ("duration", Json::from(r.duration as u64)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]))
}

// ---------------------------------------------------------------------------
// postcovid (vignette 2)
// ---------------------------------------------------------------------------

fn cmd_postcovid(argv: &[String]) -> Result<(), String> {
    let spec = [
        OptSpec::value("patients", Some("500"), "synthetic cohort size"),
        OptSpec::value("seed", Some("11"), "RNG seed"),
        OptSpec::value("corr-threshold", Some("0.4"), "exclusion correlation threshold"),
        OptSpec::flag("use-artifacts", "run correlations on PJRT artifacts"),
    ];
    if wants_help(argv) {
        print!("{}", usage("tspm postcovid", "WHO Post COVID-19 vignette", &spec));
        return Ok(());
    }
    let a = Args::parse(argv, &spec).map_err(|e| e.to_string())?;
    let mut gen_cfg = SyntheaConfig::small();
    gen_cfg.patients = a.req("patients").map_err(|e| e.to_string())?;
    gen_cfg.seed = a.req("seed").map_err(|e| e.to_string())?;
    let g = gen_cfg.generate_with_truth();
    let run = Engine::from_raw(&g.dbmart)
        .map_err(|e| e.to_string())?
        .mine(MiningConfig::default())
        .run()
        .map_err(|e| e.to_string())?;
    let db = run.db;
    let mined = run.sequences.materialize().map_err(|e| e.to_string())?;

    let covid = db
        .lookup
        .phenx_id(COVID_CODE)
        .ok_or_else(|| "no covid code in cohort".to_string())?;
    let mut cfg = PostCovidConfig::new(covid);
    cfg.corr_threshold = a.req("corr-threshold").map_err(|e| e.to_string())?;
    cfg.candidate_filter =
        Some(SYMPTOM_CODES.iter().filter_map(|s| db.lookup.phenx_id(s)).collect());

    let artifacts = if a.flag("use-artifacts") {
        Some(ArtifactSet::load(&tspm_plus::runtime::default_artifacts_dir()).map_err(|e| e.to_string())?)
    } else {
        None
    };
    let result = postcovid::identify(
        &mined.records,
        db.num_patients() as u32,
        &cfg,
        artifacts.as_ref(),
    )
    .map_err(|e| e.to_string())?;

    println!(
        "candidates: {}   confirmed: {}   excluded: {}",
        result.candidates.len(),
        result.confirmed.len(),
        result.excluded.len()
    );
    for (pid, sym) in result.confirmed.iter().take(10) {
        println!(
            "  {} has Post-COVID symptom {}",
            db.lookup.patient_name(*pid),
            db.lookup.phenx_name(*sym)
        );
    }
    let v = postcovid::validate(&result, &g.truth, &db.lookup);
    println!(
        "vs ground truth: precision {:.3}  recall {:.3}  f1 {:.3}  (tp={} fp={} fn={})",
        v.precision(),
        v.recall(),
        v.f1(),
        v.true_positives,
        v.false_positives,
        v.false_negatives
    );
    Ok(())
}

// ---------------------------------------------------------------------------
// mlho (vignette 1)
// ---------------------------------------------------------------------------

fn cmd_mlho(argv: &[String]) -> Result<(), String> {
    let spec = [
        OptSpec::value("patients", Some("400"), "synthetic cohort size"),
        OptSpec::value("top-k", Some("200"), "MSMR features to keep"),
        OptSpec::value("epochs", Some("200"), "training epochs"),
        OptSpec::flag("use-artifacts", "run MI + training on PJRT artifacts"),
    ];
    if wants_help(argv) {
        print!("{}", usage("tspm mlho", "MSMR + classifier vignette", &spec));
        return Ok(());
    }
    let a = Args::parse(argv, &spec).map_err(|e| e.to_string())?;
    let artifacts = if a.flag("use-artifacts") {
        Some(ArtifactSet::load(&tspm_plus::runtime::default_artifacts_dir()).map_err(|e| e.to_string())?)
    } else {
        None
    };
    let report = ml::mlho_vignette(
        a.req("patients").map_err(|e| e.to_string())?,
        a.req("top-k").map_err(|e| e.to_string())?,
        a.req("epochs").map_err(|e| e.to_string())?,
        artifacts.as_ref(),
    )
    .map_err(|e| e.to_string())?;
    print!("{report}");
    Ok(())
}

// ---------------------------------------------------------------------------
// bench
// ---------------------------------------------------------------------------

fn cmd_bench(argv: &[String]) -> Result<(), String> {
    let spec = [
        OptSpec::value("scale", Some("0.1"), "workload scale vs the paper's"),
        OptSpec::value("iterations", Some("3"), "iterations per row (paper: 10)"),
        OptSpec::value("json-out", None, "write machine-readable rows here"),
    ];
    if wants_help(argv) || argv.is_empty() {
        println!("usage: tspm bench <table1|table2|enduser> [options]\n");
        print!("{}", usage("tspm bench", "regenerate paper tables", &spec));
        return Ok(());
    }
    let (which, rest) = argv.split_first().unwrap();
    let a = Args::parse(rest, &spec).map_err(|e| e.to_string())?;
    let scale: f64 = a.req("scale").map_err(|e| e.to_string())?;
    let iters: usize = a.req("iterations").map_err(|e| e.to_string())?;

    let (rows, report) = match which.as_str() {
        "table1" => {
            let rows = experiments::table1(scale, iters);
            let report = experiments::table1_report(&rows);
            (rows, report)
        }
        "table2" => {
            let (total, cap, chunks) = experiments::table2_overflow_demo(scale);
            let rows = experiments::table2(scale, iters);
            let mut report = format!(
                "overflow gate: {total} sequences vs cap {cap} → adaptive partitioning uses {chunks} chunks\n"
            );
            report.push_str(&tspm_plus::bench_util::render_table(
                "Table 2 — performance benchmark (tSPM+)",
                &rows,
            ));
            (rows, report)
        }
        "enduser" => {
            let rows = experiments::enduser(iters);
            let report = tspm_plus::bench_util::render_table(
                "End-user device benchmark (1k patients × ~400 entries)",
                &rows,
            );
            (rows, report)
        }
        other => return Err(format!("unknown bench {other:?} (table1|table2|enduser)")),
    };
    print!("{report}");
    if let Some(path) = a.get("json-out") {
        std::fs::write(path, tspm_plus::bench_util::rows_to_json(&rows).to_string_pretty())
            .map_err(|e| e.to_string())?;
        println!("wrote {path}");
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// e2e
// ---------------------------------------------------------------------------

fn cmd_e2e(argv: &[String]) -> Result<(), String> {
    let spec = [
        OptSpec::value("config", None, "RunConfig JSON path (defaults inline)"),
        OptSpec::value("patients", Some("500"), "cohort size when no config given"),
        OptSpec::flag("no-artifacts", "skip PJRT; use pure-Rust analytics"),
    ];
    if wants_help(argv) {
        print!("{}", usage("tspm e2e", "full end-to-end pipeline", &spec));
        return Ok(());
    }
    let a = Args::parse(argv, &spec).map_err(|e| e.to_string())?;
    let cfg = match a.get("config") {
        Some(p) => RunConfig::load(Path::new(p)).map_err(|e| e.to_string())?,
        None => RunConfig {
            patients: a.req("patients").map_err(|e| e.to_string())?,
            ..Default::default()
        },
    };
    let artifacts = if a.flag("no-artifacts") {
        None
    } else {
        match ArtifactSet::load(Path::new(&cfg.artifacts_dir)) {
            Ok(set) => Some(set),
            Err(e) => {
                eprintln!("warning: {e}; continuing with pure-Rust analytics");
                None
            }
        }
    };
    let report =
        ml::mlho_vignette(cfg.patients, 200, 150, artifacts.as_ref()).map_err(|e| e.to_string())?;
    print!("{report}");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use tspm_plus::serve::ErrorCode;

    #[test]
    fn generic_string_errors_map_to_exit_code_1() {
        let e = CmdError::from("something broke".to_string());
        assert_eq!(e.code, 1);
        assert_eq!(CmdError::from("str form").code, 1);
    }

    #[test]
    fn artifact_open_failures_map_to_exit_code_3_and_name_the_path() {
        let missing = std::env::temp_dir().join("tspm_cli_no_such_index");
        let _ = std::fs::remove_dir_all(&missing);
        let err = open_service(&missing, DEFAULT_CACHE_BYTES)
            .map_err(|e| CmdError { code: EXIT_ARTIFACT, message: e.to_string() })
            .unwrap_err();
        assert_eq!(err.code, 3);
        assert!(err.message.contains("tspm_cli_no_such_index"), "{}", err.message);
    }

    #[test]
    fn typed_remote_errors_map_to_exit_code_4_others_to_1() {
        let remote = client_err(ServeError::Remote {
            code: ErrorCode::NotFound,
            message: "no artifact \"b\"".into(),
        });
        assert_eq!(remote.code, EXIT_REMOTE);
        assert!(remote.message.contains("not_found"), "{}", remote.message);
        assert_eq!(client_err(ServeError::Busy).code, EXIT_REMOTE);
        let io = client_err(ServeError::Io(std::io::Error::new(
            std::io::ErrorKind::ConnectionRefused,
            "refused",
        )));
        assert_eq!(io.code, 1);
    }
}
