//! [`compact`] — fold K segments into one artifact in one bounded pass.
//!
//! Compaction is two streaming k-way merges (the
//! [`crate::sparsity`] merge machinery under two different total
//! orders), never a re-sort:
//!
//! 1. the segments' seq-major data files merge under the spill order
//!    `(seq, pid, duration)`, feeding the new data file, its checksum,
//!    and the block/sequence tables (the index builder's own
//!    accumulator, so the tables come out bit-identical to a fresh
//!    build);
//! 2. the segments' **pid-major copies** merge under `(pid, seq,
//!    duration)`, deriving the new `pdata` file and per-pid table from
//!    the merge stream directly — no second full sort of the union.
//!
//! Memory is bounded by `buffer_bytes` split across the merge cursors,
//! and the output is bit-identical for every budget (merge tie-breaking
//! is positional, never buffer-dependent). The new artifact is built in
//! a `compact_tmp` staging directory, renamed to its final
//! never-reused segment name, and only then does the manifest swap to
//! it — a crash at any step leaves the old segment set fully live.

use crate::metrics::MemTracker;
use crate::mining::SeqRecord;
use crate::query::index::{
    checksum_hex, fnv1a64, write_tables_and_manifest, TableAccum, DATA_FILE,
    DEFAULT_BLOCK_RECORDS, FNV1A64_INIT, PDATA_FILE,
};
use crate::query::{PidEntry, QueryError, SeqIndex};
use crate::seqstore::{self, SeqWriter, RECORD_BYTES};
use crate::sparsity::{merge_sorted_runs_by, spill_key};
use std::io;
use std::path::{Path, PathBuf};

use super::SegmentSet;

/// Staging directory inside the set — never visible as a segment.
const COMPACT_TMP: &str = "compact_tmp";

/// Compaction knobs.
#[derive(Clone, Copy, Debug)]
pub struct CompactConfig {
    /// Records per index block of the compacted artifact
    /// ([`DEFAULT_BLOCK_RECORDS`]). Must match the block size used for
    /// a reference build when comparing artifacts bit-for-bit.
    pub block_records: usize,
    /// Total merge-buffer budget in bytes, split across the per-segment
    /// cursors and the output writer. Any value ≥ 1 works; the output
    /// is bit-identical regardless.
    pub buffer_bytes: usize,
    /// Test hook: fail with an injected IO error after this many merged
    /// records, leaving whatever partial state the failure produced.
    /// The crash-safety suite uses it to prove the old set survives.
    #[doc(hidden)]
    pub fail_after_records: Option<u64>,
}

impl Default for CompactConfig {
    fn default() -> Self {
        CompactConfig {
            block_records: DEFAULT_BLOCK_RECORDS,
            buffer_bytes: 64 << 20,
            fail_after_records: None,
        }
    }
}

/// Fold every live segment of `set` into a single fresh artifact and
/// atomically swap the manifest to it. On success the set holds exactly
/// one segment (a brand-new name — compaction never rewrites in place)
/// and the retired segment directories are removed best-effort. On
/// *any* failure the staging directory is discarded and the manifest —
/// and so every reader — still sees the old segments, untouched.
///
/// The compacted artifact is **bit-identical** to a fresh
/// [`crate::query::index::build`] over the union of the segments'
/// records at the same `block_records` (enforced by the property tests
/// in `rust/tests/ingest_conformance.rs`), so compacting is invisible
/// to every consumer of the artifact format.
pub fn compact(
    set: &mut SegmentSet,
    cfg: &CompactConfig,
    tracker: Option<&MemTracker>,
) -> Result<SeqIndex, QueryError> {
    if cfg.block_records == 0 {
        return Err(QueryError::Invalid("compact block_records must be ≥ 1".into()));
    }
    if set.is_empty() {
        return Err(QueryError::Invalid("compact needs at least one segment".into()));
    }
    let tmp = set.dir().join(COMPACT_TMP);
    if tmp.exists() {
        // A stale *directory* is debris from an interrupted compaction
        // and is safe to reclaim; anything else in the way is an error.
        std::fs::remove_dir_all(&tmp)?;
    }
    let folded = set.segments().len() as u64;
    let result = compact_impl(set, cfg, tracker, &tmp);
    if result.is_ok() {
        let reg = crate::obs::metrics::global();
        reg.counter(crate::obs::names::COMPACT_RUNS).inc();
        reg.counter(crate::obs::names::COMPACT_SEGMENTS_FOLDED).add(folded);
    } else {
        let _ = std::fs::remove_dir_all(&tmp);
    }
    result
}

fn compact_impl(
    set: &mut SegmentSet,
    cfg: &CompactConfig,
    tracker: Option<&MemTracker>,
    tmp: &Path,
) -> Result<SeqIndex, QueryError> {
    let mut segments = Vec::with_capacity(set.len());
    for dir in set.segment_dirs() {
        let idx = SeqIndex::open(&dir)?;
        if idx.pids.is_none() {
            return Err(QueryError::Invalid(format!(
                "segment {} is a v1 artifact without a pid-major copy — compact \
                 needs v2 segments",
                dir.display()
            )));
        }
        segments.push(idx);
    }
    let expected: u64 = segments.iter().map(|s| s.total_records).sum();
    let num_patients = segments.iter().map(|s| s.num_patients).max().unwrap_or(0);
    let num_phenx = segments.iter().map(|s| s.num_phenx).max().unwrap_or(0);
    // A target spec survives compaction only when every segment agrees on
    // it — a mixed set's union is *not* the output of any single targeted
    // run, so the folded artifact would lie about its own provenance.
    let unanimous_target = {
        let first = segments[0].target.clone();
        if segments.iter().all(|s| s.target == first) {
            first
        } else {
            None
        }
    };

    // One buffer slot per input cursor plus one for the output writer.
    let slot = (cfg.buffer_bytes / (segments.len() + 1)).max(RECORD_BYTES);
    let per_run = slot / RECORD_BYTES;
    std::fs::create_dir_all(tmp)?;
    if let Some(t) = tracker {
        t.add((slot * (segments.len() + 1)) as u64);
    }

    // Pass A: merge the seq-major data files in spill order, feeding
    // the data file, its checksum, and the block/seq tables.
    let data_paths: Vec<PathBuf> = segments.iter().map(|s| s.data_path.clone()).collect();
    let mut writer = SeqWriter::create_with_capacity(&tmp.join(DATA_FILE), slot)?;
    let mut tables = TableAccum::new(cfg.block_records);
    let mut data_fnv = FNV1A64_INIT;
    let mut merged = 0u64;
    let mut pass_span = crate::obs::trace::current_span("ingest.compact_merge_pass");
    merge_sorted_runs_by(&data_paths, per_run, spill_key, |r| {
        if let Some(limit) = cfg.fail_after_records {
            if merged >= limit {
                return Err(io::Error::new(
                    io::ErrorKind::Other,
                    "injected compaction failure (test hook)",
                ));
            }
        }
        writer.write(r)?;
        data_fnv = fnv1a64(data_fnv, &seqstore::encode_record(r));
        tables.push(r);
        merged += 1;
        Ok(())
    })?;
    let written = writer.finish()?;
    if let Some(s) = pass_span.as_mut() {
        s.attr("pass", "data");
        s.attr("runs_merged", data_paths.len() as u64);
        s.attr("bytes_merged", written * RECORD_BYTES as u64);
    }
    drop(pass_span);
    if written != expected {
        return Err(QueryError::Artifact(format!(
            "compaction merged {written} records, segment manifests promise {expected}"
        )));
    }
    let (blocks, seqs) = tables.finish();

    // Pass B: merge the pid-major copies in (pid, seq, duration) order —
    // the pdata file and per-pid table fall out of the stream, no
    // second sort of the union.
    let pdata_paths: Vec<PathBuf> =
        segments.iter().map(|s| s.dir.join(PDATA_FILE)).collect();
    let mut pid_counts = vec![0u64; num_patients as usize];
    let mut pwriter = SeqWriter::create_with_capacity(&tmp.join(PDATA_FILE), slot)?;
    let mut pdata_fnv = FNV1A64_INIT;
    let mut pid_err = false;
    let mut pass_span = crate::obs::trace::current_span("ingest.compact_merge_pass");
    merge_sorted_runs_by(
        &pdata_paths,
        per_run,
        |r: &SeqRecord| ((r.pid as u128) << 96) | ((r.seq as u128) << 32) | r.duration as u128,
        |r| {
            match pid_counts.get_mut(r.pid as usize) {
                Some(c) => *c += 1,
                None => {
                    pid_err = true;
                    return Err(io::Error::new(
                        io::ErrorKind::InvalidData,
                        format!("pid {} outside the dense space of {num_patients}", r.pid),
                    ));
                }
            }
            pwriter.write(r)?;
            pdata_fnv = fnv1a64(pdata_fnv, &seqstore::encode_record(r));
            Ok(())
        },
    )
    .map_err(|e| {
        if pid_err {
            QueryError::Artifact(format!("segment pid-major copy is corrupt: {e}"))
        } else {
            QueryError::Io(e)
        }
    })?;
    let pwritten = pwriter.finish()?;
    if let Some(s) = pass_span.as_mut() {
        s.attr("pass", "pdata");
        s.attr("runs_merged", pdata_paths.len() as u64);
        s.attr("bytes_merged", pwritten * RECORD_BYTES as u64);
    }
    drop(pass_span);
    if pwritten != expected {
        return Err(QueryError::Artifact(format!(
            "pid-major merge produced {pwritten} records, data merge produced {expected} \
             — the segments' copies disagree"
        )));
    }
    let mut entries = Vec::with_capacity(pid_counts.len());
    let mut start = 0u64;
    for &c in &pid_counts {
        entries.push(PidEntry { start, count: c });
        start += c;
    }
    let pid_table = Some((entries, checksum_hex(pdata_fnv)));

    write_tables_and_manifest(
        tmp,
        cfg.block_records,
        written,
        num_patients,
        num_phenx,
        data_fnv,
        blocks,
        seqs,
        pid_table,
        unanimous_target.as_ref(),
        tracker,
    )?;
    if let Some(t) = tracker {
        t.sub((slot * (segments.len() + 1)) as u64);
    }

    // Publish: rename the staged artifact to its final (never-reused)
    // segment name, then swap the manifest. Readers that opened the old
    // segments keep their file handles; new opens see only the new set.
    let new_name = format!("seg_{:04}", set.next_segment());
    let final_dir = set.dir().join(&new_name);
    std::fs::rename(tmp, &final_dir)?;
    let retired = match set.commit_replacement(new_name) {
        Ok(old) => old,
        Err(e) => {
            let _ = std::fs::remove_dir_all(&final_dir);
            return Err(e);
        }
    };
    for name in retired {
        let _ = std::fs::remove_dir_all(set.dir().join(name));
    }
    SeqIndex::open(&final_dir)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::query::index::IndexConfig;
    use crate::seqstore::SeqFileSet;
    use crate::target::TargetSpec;

    fn tmpdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("tspm_compact_{}", std::process::id()))
            .join(name);
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn fileset(dir: &Path, records: &[SeqRecord]) -> SeqFileSet {
        let path = dir.join("run.tspm");
        seqstore::write_file(&path, records).unwrap();
        SeqFileSet {
            files: vec![path],
            total_records: records.len() as u64,
            num_patients: 8,
            num_phenx: 4,
        }
    }

    fn recs(pids: std::ops::Range<u32>) -> Vec<SeqRecord> {
        pids.map(|p| SeqRecord { seq: 10001, pid: p, duration: p }).collect()
    }

    #[test]
    fn compact_emits_merge_pass_spans_and_keeps_a_unanimous_target() {
        use crate::obs::trace::{
            push_current, Clock, ManualClock, MemorySink, TraceSink, Tracer,
        };
        use std::sync::Arc;

        let dir = tmpdir("spans");
        let mut set = SegmentSet::open_or_init(&dir).unwrap();
        let spec = TargetSpec::for_codes([1, 3]);
        let cfg = IndexConfig { target: Some(spec.clone()), ..Default::default() };
        let in1 = tmpdir("spans_in1");
        let in2 = tmpdir("spans_in2");
        set.add_segment(&fileset(&in1, &recs(0..4)), &cfg, None).unwrap();
        set.add_segment(&fileset(&in2, &recs(4..8)), &cfg, None).unwrap();

        let sink = Arc::new(MemorySink::new());
        let clock = Arc::new(ManualClock::new());
        let tracer = Tracer::with_sinks(
            Some(sink.clone() as Arc<dyn TraceSink>),
            Arc::new(MemorySink::new()),
            clock.clone() as Arc<dyn Clock>,
        );
        let root = tracer.span("compact");
        let guard = push_current(&root);
        let folded = compact(&mut set, &CompactConfig::default(), None).unwrap();
        drop(guard);
        root.finish();

        assert_eq!(folded.target.as_ref(), Some(&spec), "unanimous target survives the fold");

        let passes: Vec<crate::json::Json> = sink
            .lines()
            .iter()
            .map(|l| crate::json::Json::parse(l).unwrap())
            .filter(|v| {
                v.get("name").and_then(crate::json::Json::as_str)
                    == Some("ingest.compact_merge_pass")
            })
            .collect();
        assert_eq!(passes.len(), 2, "one data pass + one pdata pass");
        let total_bytes = 8 * RECORD_BYTES as u64;
        for p in &passes {
            let attrs = p.get("attrs").expect("merge pass spans carry attrs");
            assert_eq!(
                attrs.get("runs_merged").and_then(crate::json::Json::as_u64),
                Some(2),
                "both segments feed each merge pass"
            );
            assert_eq!(
                attrs.get("bytes_merged").and_then(crate::json::Json::as_u64),
                Some(total_bytes),
                "each pass streams the whole union once"
            );
        }
        let pass_kinds: Vec<&str> = passes
            .iter()
            .map(|p| p.get("attrs").unwrap().get("pass").unwrap().as_str().unwrap())
            .collect();
        assert_eq!(pass_kinds, ["data", "pdata"]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn mixed_targets_fold_to_an_untargeted_artifact() {
        let dir = tmpdir("mixed");
        let mut set = SegmentSet::open_or_init(&dir).unwrap();
        let in1 = tmpdir("mixed_in1");
        let in2 = tmpdir("mixed_in2");
        let targeted =
            IndexConfig { target: Some(TargetSpec::for_codes([1])), ..Default::default() };
        set.add_segment(&fileset(&in1, &recs(0..4)), &targeted, None).unwrap();
        set.add_segment(&fileset(&in2, &recs(4..8)), &IndexConfig::default(), None).unwrap();
        let folded = compact(&mut set, &CompactConfig::default(), None).unwrap();
        assert!(folded.target.is_none(), "disagreeing segments must not claim a target");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
