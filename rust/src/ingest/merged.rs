//! [`MergedView`] — the full query surface over a whole segment set.
//!
//! Every answer is produced by a deterministic k-way merge over the
//! per-segment answers (ties break toward the lower-numbered segment,
//! like the spill merge in [`crate::sparsity`]), or by summing the
//! per-segment resident tables — never by materializing a union
//! artifact. Under the pid-partition contract of [`crate::ingest`],
//! every method is byte-identical to a [`QueryService`] over one
//! artifact built from the union cohort; the registered
//! `ingest_conformance` suite enforces this on every adversarial
//! cohort shape, segment split, block size, and cache setting.

use crate::mining::SeqRecord;
use crate::query::index::INDEX_FORMAT_VERSION;
use crate::query::service::{Histogram, HistogramBucket, QueryService, QueryStats, SeqSupport};
use crate::query::{QueryError, QuerySurface, SurfaceInfo};
use std::cmp::Reverse;
use std::collections::{BinaryHeap, BTreeMap};
use std::path::Path;
use std::sync::Arc;

use super::SegmentSet;

/// One query surface over N immutable segments. Cheap to construct
/// (per-segment tables are already resident in each [`QueryService`]);
/// each service keeps its own result cache, so repeated queries against
/// the view still hit per-segment caches.
pub struct MergedView {
    segments: Vec<Arc<QueryService>>,
}

/// Merge already-sorted per-segment answers into one sorted vector.
/// The heap key carries the segment index, so ties break toward the
/// lower-numbered (older) segment and the output never depends on how
/// many segments the records happen to be split across.
fn merge_sorted<T: Copy>(parts: &[Arc<Vec<T>>], key: impl Fn(&T) -> u128) -> Vec<T> {
    let mut out = Vec::with_capacity(parts.iter().map(|p| p.len()).sum());
    let mut pos = vec![0usize; parts.len()];
    let mut heap: BinaryHeap<Reverse<(u128, usize)>> = BinaryHeap::new();
    for (i, p) in parts.iter().enumerate() {
        if let Some(first) = p.first() {
            heap.push(Reverse((key(first), i)));
        }
    }
    while let Some(Reverse((_, i))) = heap.pop() {
        out.push(parts[i][pos[i]]);
        pos[i] += 1;
        if let Some(next) = parts[i].get(pos[i]) {
            heap.push(Reverse((key(next), i)));
        }
    }
    out
}

impl MergedView {
    /// View over an explicit list of opened segment services, oldest
    /// first (the order fixes merge tie-breaking).
    pub fn new(segments: Vec<Arc<QueryService>>) -> MergedView {
        MergedView { segments }
    }

    /// Open every live segment of the set at `set_dir`, giving each
    /// segment's service a result cache of `cache_bytes` (0 disables
    /// caching, as for [`QueryService::open_with_cache`]).
    pub fn open(set_dir: &Path, cache_bytes: usize) -> Result<MergedView, QueryError> {
        let set = SegmentSet::open(set_dir)?;
        let mut segments = Vec::with_capacity(set.len());
        for dir in set.segment_dirs() {
            segments.push(Arc::new(QueryService::open_with_cache(&dir, cache_bytes)?));
        }
        Ok(MergedView { segments })
    }

    /// Number of segments behind the view.
    pub fn num_segments(&self) -> usize {
        self.segments.len()
    }

    /// The per-segment services, oldest first.
    pub fn services(&self) -> &[Arc<QueryService>] {
        &self.segments
    }
}

impl QuerySurface for MergedView {
    fn by_sequence(&self, seq: u64) -> Result<Arc<Vec<SeqRecord>>, QueryError> {
        let mut parts = Vec::with_capacity(self.segments.len());
        for svc in &self.segments {
            parts.push(svc.by_sequence(seq)?);
        }
        // Segment runs are (pid, duration)-sorted; so is the merge.
        Ok(Arc::new(merge_sorted(&parts, |r| {
            ((r.pid as u128) << 32) | r.duration as u128
        })))
    }

    fn by_patient(&self, pid: u32) -> Result<Arc<Vec<SeqRecord>>, QueryError> {
        let mut parts = Vec::with_capacity(self.segments.len());
        for svc in &self.segments {
            parts.push(svc.by_patient(pid)?);
        }
        // Per-patient runs are (seq, duration)-sorted; so is the merge.
        Ok(Arc::new(merge_sorted(&parts, |r| {
            ((r.seq as u128) << 32) | r.duration as u128
        })))
    }

    fn visit_patient(
        &self,
        pid: u32,
        f: &mut dyn FnMut(&[SeqRecord]) -> Result<(), QueryError>,
    ) -> Result<u64, QueryError> {
        // The cross-segment merge needs the whole patient anyway, so
        // the chunk bound here is one patient: materialize the merged
        // run once and emit it as a single chunk.
        let recs = self.by_patient(pid)?;
        if !recs.is_empty() {
            f(&recs)?;
        }
        Ok(recs.len() as u64)
    }

    fn patients_with(
        &self,
        seq: u64,
        dur_min: u32,
        dur_max: u32,
    ) -> Result<Arc<Vec<u32>>, QueryError> {
        let mut parts = Vec::with_capacity(self.segments.len());
        for svc in &self.segments {
            parts.push(svc.patients_with(seq, dur_min, dur_max)?);
        }
        let mut out = merge_sorted(&parts, |&pid| pid as u128);
        // Segments partition patients, so duplicates can only come from
        // a violated contract — dedup keeps the answer well-formed
        // (ascending distinct pids) regardless.
        out.dedup();
        Ok(Arc::new(out))
    }

    fn top_k_by_support(&self, k: usize) -> Result<Arc<Vec<SeqSupport>>, QueryError> {
        // Sum supports across segments *before* ranking. Patient counts
        // add exactly because segments partition patients. The ranking
        // order is the documented total order of the query surface —
        // support descending, then seq ascending — applied to the
        // summed supports, so the result is identical for any segment
        // layout (including one segment, i.e. a plain artifact).
        let mut agg: BTreeMap<u64, (u32, u64)> = BTreeMap::new();
        for svc in &self.segments {
            for e in &svc.index().seqs {
                let slot = agg.entry(e.seq).or_insert((0, 0));
                slot.0 += e.patients;
                slot.1 += e.count;
            }
        }
        let mut v: Vec<SeqSupport> = agg
            .into_iter()
            .map(|(seq, (patients, records))| SeqSupport { seq, patients, records })
            .collect();
        v.sort_unstable_by(|a, b| b.patients.cmp(&a.patients).then(a.seq.cmp(&b.seq)));
        v.truncate(k);
        Ok(Arc::new(v))
    }

    fn duration_histogram(
        &self,
        seq: u64,
        n_buckets: usize,
    ) -> Result<Arc<Histogram>, QueryError> {
        if n_buckets == 0 {
            return Err(QueryError::Invalid("histogram needs at least one bucket".into()));
        }
        // Global duration bounds and total: fold the per-segment table
        // entries exactly the way the index builder folds records, so
        // the bucket layout matches a union artifact's bit for bit.
        let mut global: Option<(u32, u32, u64)> = None;
        for svc in &self.segments {
            if let Some(e) = svc.index().seq_entry(seq) {
                if e.dur_max < e.dur_min {
                    return Err(QueryError::Artifact(format!(
                        "{}: sequence {seq} has duration bounds [{}, {}] — the \
                         sequence table is corrupt",
                        svc.index().data_path.display(),
                        e.dur_min,
                        e.dur_max
                    )));
                }
                global = Some(match global {
                    None => (e.dur_min, e.dur_max, e.count),
                    Some((lo, hi, n)) => {
                        (lo.min(e.dur_min), hi.max(e.dur_max), n + e.count)
                    }
                });
            }
        }
        let hist = match global {
            None => Histogram { seq, dur_min: 0, dur_max: 0, total: 0, buckets: Vec::new() },
            Some((dur_min, dur_max, total)) => {
                let span = (dur_max - dur_min) as u64 + 1;
                let width = span.div_ceil(n_buckets as u64).max(1);
                let used = span.div_ceil(width) as usize;
                let mut counts = vec![0u64; used];
                for svc in &self.segments {
                    let Some(e) = svc.index().seq_entry(seq).copied() else { continue };
                    for r in svc.by_sequence(seq)?.iter() {
                        if r.duration < e.dur_min || r.duration > e.dur_max {
                            return Err(QueryError::Artifact(format!(
                                "{}: sequence {seq} has a record with duration {}, \
                                 outside the index entry's [{}, {}] — the segment \
                                 is corrupt",
                                svc.index().data_path.display(),
                                r.duration,
                                e.dur_min,
                                e.dur_max
                            )));
                        }
                        // In global bounds by the per-segment check, so
                        // the bucket index stays in range.
                        counts[((r.duration - dur_min) as u64 / width) as usize] += 1;
                    }
                }
                let buckets = counts
                    .iter()
                    .enumerate()
                    .map(|(i, &count)| {
                        let lo = dur_min as u64 + i as u64 * width;
                        let hi = (lo + width - 1).min(dur_max as u64);
                        HistogramBucket { lo: lo as u32, hi: hi as u32, count }
                    })
                    .collect();
                Histogram { seq, dur_min, dur_max, total, buckets }
            }
        };
        Ok(Arc::new(hist))
    }

    fn stats(&self) -> QueryStats {
        let mut total = QueryStats::default();
        for svc in &self.segments {
            let s = svc.stats();
            total.hits += s.hits;
            total.misses += s.misses;
            total.evictions += s.evictions;
            total.cached_entries += s.cached_entries;
            total.cached_bytes += s.cached_bytes;
            total.logical_bytes_read += s.logical_bytes_read;
        }
        total
    }

    fn describe(&self) -> SurfaceInfo {
        let mut seqs = std::collections::BTreeSet::new();
        let mut records = 0u64;
        let mut patients = 0u32;
        let mut version = 0u64;
        for svc in &self.segments {
            let idx = svc.index();
            records += idx.total_records;
            patients = patients.max(idx.num_patients);
            version = version.max(idx.version);
            for e in &idx.seqs {
                seqs.insert(e.seq);
            }
        }
        if self.segments.is_empty() {
            version = INDEX_FORMAT_VERSION;
        }
        // A merged view reports a target only when every segment was
        // mined under the *same* spec — a mixed set's union is not the
        // output of any single targeted run (same rule as `compact`).
        let target = match self.segments.first() {
            Some(first) => {
                let spec = first.index().target.clone();
                if self.segments.iter().all(|s| s.index().target == spec) {
                    spec.map(|t| t.render())
                } else {
                    None
                }
            }
            None => None,
        };
        SurfaceInfo { records, sequences: seqs.len() as u64, patients, version, target }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::query::index::{build, IndexConfig};
    use crate::seqstore::{self, SeqFileSet};
    use std::path::PathBuf;

    fn tmpdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("tspm_merged_{}", std::process::id()))
            .join(name);
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    /// Build one artifact from `records` (already (seq, pid, duration)
    /// sorted) and return its service.
    fn service(dir: &Path, records: &[SeqRecord], num_patients: u32) -> Arc<QueryService> {
        let run = dir.join("run.tspm");
        seqstore::write_file(&run, records).unwrap();
        let input = SeqFileSet {
            files: vec![run],
            total_records: records.len() as u64,
            num_patients,
            num_phenx: 5,
        };
        let idx = build(
            &input,
            &dir.join("idx"),
            &IndexConfig { block_records: 3, ..Default::default() },
            None,
        )
        .unwrap();
        Arc::new(QueryService::from_index(idx, 0))
    }

    fn fixture() -> Vec<SeqRecord> {
        let mut v = Vec::new();
        for pid in 0..6u32 {
            for seq in [2u64, 40, 41] {
                v.push(SeqRecord { seq, pid, duration: pid * 2 + seq as u32 });
            }
        }
        // pid 0 gets an extra record of seq 2 at a duplicate duration.
        v.push(SeqRecord { seq: 2, pid: 0, duration: 2 });
        v.sort_unstable_by_key(|r| (r.seq, r.pid, r.duration));
        v
    }

    fn split_by_pid(records: &[SeqRecord], groups: &[&[u32]]) -> Vec<Vec<SeqRecord>> {
        groups
            .iter()
            .map(|g| records.iter().copied().filter(|r| g.contains(&r.pid)).collect())
            .collect()
    }

    #[test]
    fn merged_answers_equal_single_artifact_answers() {
        let dir = tmpdir("equal");
        let all = fixture();
        let full = service(&dir.join("full"), &all, 6);
        let parts = split_by_pid(&all, &[&[0, 3], &[1, 4, 5], &[2]]);
        let view = MergedView::new(
            parts
                .iter()
                .enumerate()
                .map(|(i, p)| service(&dir.join(format!("s{i}")), p, 6))
                .collect(),
        );
        assert_eq!(view.num_segments(), 3);
        for seq in [2u64, 40, 41, 999] {
            assert_eq!(*view.by_sequence(seq).unwrap(), *full.by_sequence(seq).unwrap());
            assert_eq!(
                *view.duration_histogram(seq, 4).unwrap(),
                *full.duration_histogram(seq, 4).unwrap()
            );
            assert_eq!(
                *view.patients_with(seq, 0, 8).unwrap(),
                *full.patients_with(seq, 0, 8).unwrap()
            );
        }
        for pid in 0..7u32 {
            assert_eq!(*view.by_patient(pid).unwrap(), *full.by_patient(pid).unwrap());
        }
        for k in [0usize, 1, 2, 3, 10] {
            assert_eq!(
                *view.top_k_by_support(k).unwrap(),
                *full.top_k_by_support(k).unwrap()
            );
        }
        let info = view.describe();
        assert_eq!(info.records, all.len() as u64);
        assert_eq!(info.sequences, 3);
        assert_eq!(info.patients, 6);
    }

    #[test]
    fn top_k_ties_rank_by_seq_ascending_across_any_layout() {
        // seqs 40 and 41 both have support 6; their summed cross-segment
        // supports tie, so the documented order must put 40 first.
        let dir = tmpdir("ties");
        let all = fixture();
        let parts = split_by_pid(&all, &[&[5, 0], &[4, 1, 2, 3]]);
        let view = MergedView::new(
            parts
                .iter()
                .enumerate()
                .map(|(i, p)| service(&dir.join(format!("s{i}")), p, 6))
                .collect(),
        );
        let top = view.top_k_by_support(3).unwrap();
        let order: Vec<u64> = top.iter().map(|s| s.seq).collect();
        assert_eq!(order, vec![2, 40, 41]);
        assert_eq!(top[1].patients, top[2].patients);
    }

    #[test]
    fn zero_buckets_is_invalid_and_empty_view_answers_empty() {
        let view = MergedView::new(Vec::new());
        assert!(matches!(view.duration_histogram(1, 0), Err(QueryError::Invalid(_))));
        assert!(view.by_sequence(1).unwrap().is_empty());
        assert!(view.by_patient(1).unwrap().is_empty());
        assert!(view.top_k_by_support(5).unwrap().is_empty());
        let h = view.duration_histogram(1, 3).unwrap();
        assert_eq!(h.total, 0);
        assert!(h.buckets.is_empty());
        assert_eq!(view.describe().records, 0);
    }
}
