//! Incremental ingest — immutable index segments under one manifest.
//!
//! Every [`crate::query::SeqIndex`] artifact is immutable once built,
//! which makes it a natural **segment** of a growing dataset: instead of
//! re-mining the whole cohort when a new batch of records arrives,
//! `tspm ingest` mines just the delta into its *own* artifact and adds
//! it to a [`SegmentSet`]. Queries then run over all segments at once
//! through [`MergedView`] (the [`crate::query::QuerySurface`] trait
//! implemented by bounded k-way merge), and [`compact`] periodically
//! folds K segments back into one artifact in a single bounded-memory
//! merge pass. This is the LSM shape: writes append segments, reads
//! merge, compaction restores the one-artifact fast path.
//!
//! ## The segment-set manifest
//!
//! A segment set is a directory holding segment subdirectories (each a
//! complete v2 index artifact) plus one manifest file:
//!
//! ```text
//! segments.json   {"format": "tspm-segset", "version": 1,
//!                  "next_segment": N, "segments": ["seg_0000", ...],
//!                  "checksum": "<fnv-1a 64 hex>"}
//! seg_0000/       immutable v2 index artifact (manifest.json, data,
//! seg_0001/       blocks, seqs, pdata, pids) — never rewritten
//! lookup.json     cohort string tables, extended by each ingest so
//!                 delta cohorts share one dense pid/phenX id space
//! ```
//!
//! `segments.json` is the *only* mutable file, and it is only ever
//! replaced atomically: writers serialize the new manifest to
//! `segments.json.tmp` and `rename(2)` it over the old one, so a reader
//! (or a crash) sees either the old complete set or the new complete
//! set, never a mix. Segment names come from the monotonically
//! increasing `next_segment` counter and are **never reused**, so a
//! retired segment directory can linger (crash between rename and
//! cleanup) without ever being mistaken for live data. The `checksum`
//! field is FNV-1a 64 over the segment names and the counter, so a
//! truncated or hand-edited manifest is a typed error, not a silently
//! smaller set.
//!
//! ## Compatibility guarantee
//!
//! The `(format, version)` pair gates every read, exactly like the
//! artifact manifests documented in [`crate::query`]: [`SegmentSet::open`]
//! accepts only `"tspm-segset"` version [`SEGSET_FORMAT_VERSION`] and
//! fails loudly on anything else. The segments themselves are ordinary
//! v2 artifacts under the [`crate::query`] compatibility rules — a
//! segment set never changes what is *inside* a segment, so artifact
//! readers and segment readers can evolve independently.
//!
//! ## The correctness contract
//!
//! Segments partition the cohort **by patient**: one patient's records
//! live in exactly one segment (the CLI enforces this by splitting
//! deltas at patient boundaries, and per-segment distinct-patient
//! counts stay exact under that partition). Under this contract the
//! whole query surface over a [`MergedView`] is byte-identical to a
//! single artifact built from the union cohort, and a compacted
//! artifact is bit-identical to a fresh full-cohort index — both
//! properties enforced by `rust/tests/ingest_conformance.rs` on every
//! adversarial cohort shape.

pub mod compact;
pub mod merged;

pub use compact::{compact, CompactConfig};
pub use merged::MergedView;

use crate::metrics::MemTracker;
use crate::query::index::{self, checksum_hex, fnv1a64, IndexConfig, FNV1A64_INIT};
use crate::query::{QueryError, SeqIndex};
use crate::seqstore::SeqFileSet;
use std::path::{Path, PathBuf};

/// Manifest `format` tag of a segment set.
pub const SEGSET_FORMAT: &str = "tspm-segset";
/// Current (and only) segment-set manifest version.
pub const SEGSET_FORMAT_VERSION: u64 = 1;

/// The one mutable file of a segment set — always swapped atomically.
const SEGSET_MANIFEST: &str = "segments.json";

/// A set of immutable index segments under one atomically-swapped
/// manifest. See the [module docs](self) for the on-disk format.
#[derive(Debug)]
pub struct SegmentSet {
    dir: PathBuf,
    segments: Vec<String>,
    next_segment: u64,
}

/// Checksum pinned by the manifest: the segment names and the counter,
/// in order, with a separator no name can contain.
fn manifest_checksum(segments: &[String], next_segment: u64) -> String {
    let mut h = FNV1A64_INIT;
    for name in segments {
        h = fnv1a64(h, name.as_bytes());
        h = fnv1a64(h, b"\n");
    }
    h = fnv1a64(h, &next_segment.to_le_bytes());
    checksum_hex(h)
}

impl SegmentSet {
    /// Create an empty segment set at `dir` (created if missing) and
    /// commit its manifest. Fails if a manifest already exists there.
    pub fn init(dir: &Path) -> Result<SegmentSet, QueryError> {
        std::fs::create_dir_all(dir)?;
        if dir.join(SEGSET_MANIFEST).exists() {
            return Err(QueryError::Invalid(format!(
                "segment set already initialized at {}",
                dir.display()
            )));
        }
        let set =
            SegmentSet { dir: dir.to_path_buf(), segments: Vec::new(), next_segment: 0 };
        set.commit()?;
        Ok(set)
    }

    /// Open the segment set at `dir`, validating manifest format,
    /// version and checksum, and that every listed segment directory
    /// exists.
    pub fn open(dir: &Path) -> Result<SegmentSet, QueryError> {
        let path = dir.join(SEGSET_MANIFEST);
        let text = std::fs::read_to_string(&path).map_err(|e| {
            QueryError::Artifact(format!("cannot read {}: {e}", path.display()))
        })?;
        let v = crate::json::Json::parse(&text).map_err(|e| {
            QueryError::Artifact(format!("bad json in {}: {e}", path.display()))
        })?;
        let field = |k: &str| {
            v.get(k).ok_or_else(|| {
                QueryError::Artifact(format!("{} missing field {k:?}", path.display()))
            })
        };
        let format = field("format")?.as_str().unwrap_or_default().to_string();
        if format != SEGSET_FORMAT {
            return Err(QueryError::Artifact(format!(
                "{} has format {format:?}, want {SEGSET_FORMAT:?}",
                path.display()
            )));
        }
        let version = field("version")?.as_u64().unwrap_or(0);
        if version != SEGSET_FORMAT_VERSION {
            return Err(QueryError::Artifact(format!(
                "{} has version {version}, this build reads {SEGSET_FORMAT_VERSION}",
                path.display()
            )));
        }
        let next_segment = field("next_segment")?.as_u64().ok_or_else(|| {
            QueryError::Artifact(format!("{} next_segment is not a u64", path.display()))
        })?;
        let mut segments = Vec::new();
        for s in field("segments")?.as_arr().ok_or_else(|| {
            QueryError::Artifact(format!("{} segments is not an array", path.display()))
        })? {
            let name = s.as_str().ok_or_else(|| {
                QueryError::Artifact(format!(
                    "{} segments holds a non-string entry",
                    path.display()
                ))
            })?;
            segments.push(name.to_string());
        }
        let want = field("checksum")?.as_str().unwrap_or_default().to_string();
        let got = manifest_checksum(&segments, next_segment);
        if want != got {
            return Err(QueryError::Artifact(format!(
                "{} checksum mismatch: manifest says {want}, contents hash to {got}",
                path.display()
            )));
        }
        for name in &segments {
            if !dir.join(name).join("manifest.json").is_file() {
                return Err(QueryError::Artifact(format!(
                    "segment set lists {name:?} but {} has no such artifact",
                    dir.display()
                )));
            }
        }
        Ok(SegmentSet { dir: dir.to_path_buf(), segments, next_segment })
    }

    /// [`open`](SegmentSet::open) if a manifest exists at `dir`, else
    /// [`init`](SegmentSet::init) — the `tspm ingest` entry point.
    pub fn open_or_init(dir: &Path) -> Result<SegmentSet, QueryError> {
        if dir.join(SEGSET_MANIFEST).is_file() {
            SegmentSet::open(dir)
        } else {
            SegmentSet::init(dir)
        }
    }

    /// The set's directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Live segment names, oldest first.
    pub fn segments(&self) -> &[String] {
        &self.segments
    }

    /// Absolute directories of the live segments, oldest first.
    pub fn segment_dirs(&self) -> Vec<PathBuf> {
        self.segments.iter().map(|s| self.dir.join(s)).collect()
    }

    /// Number of live segments.
    pub fn len(&self) -> usize {
        self.segments.len()
    }

    /// True when the set holds no segments yet.
    pub fn is_empty(&self) -> bool {
        self.segments.is_empty()
    }

    /// The next segment name the set will allocate (for tests/tools).
    pub fn next_segment(&self) -> u64 {
        self.next_segment
    }

    /// Atomically replace `segments.json` with the current in-memory
    /// state: serialize to `segments.json.tmp`, then `rename(2)` over
    /// the live manifest. A reader never observes a partial manifest.
    pub(crate) fn commit(&self) -> Result<(), QueryError> {
        use crate::json::Json;
        let m = Json::obj(vec![
            ("format", Json::from(SEGSET_FORMAT)),
            ("version", Json::from(SEGSET_FORMAT_VERSION)),
            ("next_segment", Json::from(self.next_segment)),
            (
                "segments",
                Json::Arr(self.segments.iter().map(|s| Json::from(s.as_str())).collect()),
            ),
            (
                "checksum",
                Json::from(manifest_checksum(&self.segments, self.next_segment).as_str()),
            ),
        ]);
        let tmp = self.dir.join(format!("{SEGSET_MANIFEST}.tmp"));
        std::fs::write(&tmp, m.to_string_pretty())?;
        std::fs::rename(&tmp, self.dir.join(SEGSET_MANIFEST))?;
        Ok(())
    }

    /// Swap the whole live set for the single segment `name` (already
    /// renamed into place by the compactor) and commit. Returns the
    /// retired segment names for cleanup. On a failed commit the
    /// in-memory state rolls back to match the still-live old manifest;
    /// the caller owns removing the orphaned new directory.
    pub(crate) fn commit_replacement(
        &mut self,
        name: String,
    ) -> Result<Vec<String>, QueryError> {
        let old = std::mem::replace(&mut self.segments, vec![name]);
        self.next_segment += 1;
        if let Err(e) = self.commit() {
            self.segments = old;
            self.next_segment -= 1;
            return Err(e);
        }
        Ok(old)
    }

    /// Build `input` (a sorted, screened record run — the same thing
    /// `tspm index` consumes) into a brand-new segment and commit it to
    /// the set. The artifact is built in a hidden temp directory and
    /// renamed into place before the manifest swap, so a crash at any
    /// point leaves either the old set or the new set — never a
    /// half-built segment behind a live manifest entry.
    pub fn add_segment(
        &mut self,
        input: &SeqFileSet,
        cfg: &IndexConfig,
        tracker: Option<&MemTracker>,
    ) -> Result<SeqIndex, QueryError> {
        let name = format!("seg_{:04}", self.next_segment);
        let tmp = self.dir.join(format!(".seg_{:04}.tmp", self.next_segment));
        if tmp.exists() {
            std::fs::remove_dir_all(&tmp)?;
        }
        if let Err(e) = index::build(input, &tmp, cfg, tracker) {
            let _ = std::fs::remove_dir_all(&tmp);
            return Err(e);
        }
        let final_dir = self.dir.join(&name);
        if let Err(e) = std::fs::rename(&tmp, &final_dir) {
            let _ = std::fs::remove_dir_all(&tmp);
            return Err(e.into());
        }
        self.segments.push(name);
        self.next_segment += 1;
        if let Err(e) = self.commit() {
            // Roll back the in-memory state to match the live manifest;
            // the orphan directory is harmless (its name is spent).
            let name = self.segments.pop().expect("just pushed");
            self.next_segment -= 1;
            let _ = std::fs::remove_dir_all(self.dir.join(&name));
            return Err(e);
        }
        // Counted only after the manifest commit — the metric reflects
        // durable segments, not attempts.
        crate::obs::metrics::global()
            .counter(crate::obs::names::INGEST_SEGMENTS_COMMITTED)
            .inc();
        SeqIndex::open(&final_dir)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mining::SeqRecord;
    use crate::seqstore;

    fn tmpdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("tspm_ingest_{}", std::process::id()))
            .join(name);
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn fileset(dir: &Path, records: &[SeqRecord]) -> SeqFileSet {
        let path = dir.join("run.tspm");
        seqstore::write_file(&path, records).unwrap();
        SeqFileSet {
            files: vec![path],
            total_records: records.len() as u64,
            num_patients: 8,
            num_phenx: 4,
        }
    }

    #[test]
    fn init_open_roundtrip_and_checksum_gate() {
        let dir = tmpdir("roundtrip");
        let set = SegmentSet::init(&dir).unwrap();
        assert!(set.is_empty());
        assert!(SegmentSet::init(&dir).is_err(), "double init must fail");
        let reopened = SegmentSet::open(&dir).unwrap();
        assert_eq!(reopened.segments(), &[] as &[String]);
        assert_eq!(reopened.next_segment(), 0);

        // A hand-edited manifest (extra segment, stale checksum) is a
        // typed artifact error, not a silently different set.
        let path = dir.join(SEGSET_MANIFEST);
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, text.replace("[]", "[\"seg_0000\"]")).unwrap();
        match SegmentSet::open(&dir) {
            Err(QueryError::Artifact(msg)) => assert!(msg.contains("checksum"), "{msg}"),
            other => panic!("expected checksum error, got {other:?}"),
        }
    }

    #[test]
    fn add_segment_commits_atomically_and_numbers_monotonically() {
        let dir = tmpdir("add");
        let mut set = SegmentSet::open_or_init(&dir).unwrap();
        let recs: Vec<SeqRecord> =
            (0..8).map(|p| SeqRecord { seq: 5, pid: p, duration: p }).collect();
        let sub = tmpdir("add_input");
        let idx = set
            .add_segment(&fileset(&sub, &recs), &IndexConfig::default(), None)
            .unwrap();
        assert_eq!(idx.total_records, 8);
        assert_eq!(set.segments(), &["seg_0000".to_string()]);
        assert_eq!(set.next_segment(), 1);
        // The committed manifest round-trips and the artifact opens.
        let reopened = SegmentSet::open(&dir).unwrap();
        assert_eq!(reopened.segments(), set.segments());
        SeqIndex::open(&reopened.segment_dirs()[0]).unwrap();
        // No temp debris.
        assert!(!dir.join(".seg_0000.tmp").exists());
        assert!(!dir.join(format!("{SEGSET_MANIFEST}.tmp")).exists());
    }

    #[test]
    fn failed_build_leaves_manifest_and_disk_untouched() {
        let dir = tmpdir("fail");
        let mut set = SegmentSet::open_or_init(&dir).unwrap();
        let before = std::fs::read_to_string(dir.join(SEGSET_MANIFEST)).unwrap();
        // Unsorted input: index::build rejects it mid-stream.
        let recs =
            vec![SeqRecord { seq: 9, pid: 0, duration: 0 }, SeqRecord { seq: 1, pid: 0, duration: 0 }];
        let sub = tmpdir("fail_input");
        assert!(set
            .add_segment(&fileset(&sub, &recs), &IndexConfig::default(), None)
            .is_err());
        assert_eq!(set.next_segment(), 0, "failed add must not burn a name");
        let after = std::fs::read_to_string(dir.join(SEGSET_MANIFEST)).unwrap();
        assert_eq!(before, after, "manifest bytes must be untouched");
        assert!(!dir.join("seg_0000").exists());
        assert!(!dir.join(".seg_0000.tmp").exists());
    }
}
