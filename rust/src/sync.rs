//! Synchronization shim — `std::sync` normally, `loom::sync` under
//! model checking.
//!
//! The repo's strongest contract is byte-identical output across every
//! thread count, schedule and memory budget. Differential tests can
//! only sample schedules; **loom** model-checks them exhaustively. This
//! module is the seam that makes that possible without forking the
//! production code: every concurrency-bearing module ([`crate::par`],
//! the sharded merge in [`crate::mining`], the counted cache in
//! [`crate::query`], the hot-swap registry in [`crate::serve`]) imports
//! its primitives from here instead of `std::sync`.
//!
//! * In a **default build** (`cfg(not(loom))`) everything below is a
//!   plain re-export of the `std::sync` type of the same name — the
//!   shim compiles away entirely. The `shim_reexports_are_std_types`
//!   smoke test pins this: the re-exports are the *identical* types
//!   (same `TypeId`, same size), so non-loom builds are bit-for-bit
//!   unaffected.
//! * Under `RUSTFLAGS="--cfg loom"` the same names resolve to
//!   `loom::sync` equivalents, and the `#[cfg(loom)]` test suites
//!   (filter: `loom`) explore every interleaving the modeled protocols
//!   allow. The `loom` crate is deliberately **not** a committed
//!   dependency (the build must stay hermetic); the loom CI lane adds
//!   it on the fly:
//!
//! ```text
//! cargo add loom@0.7 --dev
//! RUSTFLAGS="--cfg loom" cargo test --release --lib loom
//! ```
//!
//! (A dev-dependency suffices: the `--lib` test target links
//! dev-dependencies everywhere in the crate, and only test builds ever
//! set `--cfg loom`.)
//!
//! ## Poison policy
//!
//! A panicking holder must never wedge the whole process: one
//! connection thread dying inside the admission-control semaphore or
//! the query cache must not turn every later `lock()` into a panic.
//! The [`lock_ignore_poison`] / [`read_ignore_poison`] /
//! [`write_ignore_poison`] / [`wait_ignore_poison`] helpers recover the
//! guard from a poisoned lock via `PoisonError::into_inner`. This is
//! sound for every protected structure in this crate because each one
//! is updated to a consistent state before anything that can panic runs
//! (counters are plain integer writes; the LRU's bookkeeping never
//! unwinds mid-update except in the caller-supplied `Clone`, which runs
//! after the map is consistent).

#[cfg(not(loom))]
pub use std::sync::{
    Arc, Condvar, Mutex, MutexGuard, OnceLock, RwLock, RwLockReadGuard, RwLockWriteGuard,
};

/// Atomic types and orderings — `std::sync::atomic` or
/// `loom::sync::atomic`.
#[cfg(not(loom))]
pub mod atomic {
    pub use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
}

#[cfg(loom)]
pub use loom::sync::{
    Arc, Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard,
};

#[cfg(loom)]
pub mod atomic {
    pub use loom::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
}

/// Write-once cell under loom. `loom` ships no `OnceLock`, so the model
/// build substitutes a `Mutex<Option<T>>` with the same `set` /
/// `into_inner` subset the sharded merge uses; the *protocol* under
/// test (claim a slot index atomically, fill it exactly once, drain in
/// slot order) is unchanged.
#[cfg(loom)]
pub struct OnceLock<T> {
    inner: Mutex<Option<T>>,
}

#[cfg(loom)]
impl<T> OnceLock<T> {
    pub fn new() -> OnceLock<T> {
        OnceLock { inner: Mutex::new(None) }
    }

    /// Store `value` if the cell is empty; returns it back otherwise —
    /// same contract as `std::sync::OnceLock::set`.
    pub fn set(&self, value: T) -> Result<(), T> {
        let mut slot = lock_ignore_poison(&self.inner);
        if slot.is_some() {
            return Err(value);
        }
        *slot = Some(value);
        Ok(())
    }

    /// Consume the cell, returning its value if one was ever set.
    pub fn into_inner(self) -> Option<T> {
        lock_ignore_poison(&self.inner).take()
    }
}

#[cfg(loom)]
impl<T> Default for OnceLock<T> {
    fn default() -> Self {
        OnceLock::new()
    }
}

/// Lock a mutex, recovering the guard when a previous holder panicked.
/// See the module docs for why this is sound here.
pub fn lock_ignore_poison<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// [`RwLock::read`] with poison recovery.
pub fn read_ignore_poison<T>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// [`RwLock::write`] with poison recovery.
pub fn write_ignore_poison<T>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// [`Condvar::wait`] with poison recovery — returns the reacquired
/// guard even when another holder of the same mutex panicked while the
/// waiter slept.
pub fn wait_ignore_poison<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
) -> MutexGuard<'a, T> {
    cv.wait(guard).unwrap_or_else(std::sync::PoisonError::into_inner)
}

#[cfg(all(test, not(loom)))]
mod tests {
    use std::any::TypeId;

    /// The default-build contract: every shim re-export IS the
    /// `std::sync` type — same `TypeId`, same layout — so non-loom
    /// builds pay nothing and break nothing.
    #[test]
    fn shim_reexports_are_std_types() {
        assert_eq!(
            TypeId::of::<super::Mutex<u64>>(),
            TypeId::of::<std::sync::Mutex<u64>>()
        );
        assert_eq!(TypeId::of::<super::Condvar>(), TypeId::of::<std::sync::Condvar>());
        assert_eq!(
            TypeId::of::<super::RwLock<Vec<u8>>>(),
            TypeId::of::<std::sync::RwLock<Vec<u8>>>()
        );
        assert_eq!(
            TypeId::of::<super::OnceLock<String>>(),
            TypeId::of::<std::sync::OnceLock<String>>()
        );
        assert_eq!(
            TypeId::of::<super::Arc<u32>>(),
            TypeId::of::<std::sync::Arc<u32>>()
        );
        assert_eq!(
            TypeId::of::<super::atomic::AtomicUsize>(),
            TypeId::of::<std::sync::atomic::AtomicUsize>()
        );
        assert_eq!(
            TypeId::of::<super::atomic::AtomicU64>(),
            TypeId::of::<std::sync::atomic::AtomicU64>()
        );
        assert_eq!(
            std::mem::size_of::<super::Mutex<u64>>(),
            std::mem::size_of::<std::sync::Mutex<u64>>()
        );
        assert_eq!(
            std::mem::size_of::<super::atomic::AtomicUsize>(),
            std::mem::size_of::<std::sync::atomic::AtomicUsize>()
        );
    }

    #[test]
    fn lock_ignore_poison_recovers_a_poisoned_mutex() {
        let m = super::Mutex::new(7u32);
        // A holder panics with the guard live → the mutex is poisoned.
        let res = std::thread::scope(|s| {
            s.spawn(|| {
                let _g = m.lock().unwrap();
                panic!("holder dies");
            })
            .join()
        });
        assert!(res.is_err());
        assert!(m.lock().is_err(), "plain lock() sees the poison");
        // Recovery: the data is still there and writable.
        let mut g = super::lock_ignore_poison(&m);
        assert_eq!(*g, 7);
        *g = 8;
        drop(g);
        assert_eq!(*super::lock_ignore_poison(&m), 8);
    }

    #[test]
    fn rwlock_ignore_poison_recovers_both_sides() {
        let l = super::RwLock::new(1u32);
        let res = std::thread::scope(|s| {
            s.spawn(|| {
                let _g = l.write().unwrap();
                panic!("writer dies");
            })
            .join()
        });
        assert!(res.is_err());
        assert_eq!(*super::read_ignore_poison(&l), 1);
        *super::write_ignore_poison(&l) = 2;
        assert_eq!(*super::read_ignore_poison(&l), 2);
    }
}
