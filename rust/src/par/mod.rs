//! Scoped-thread parallel-map substrate — the OpenMP stand-in.
//!
//! The paper parallelizes tSPM+ with OpenMP `parallel for` over patient
//! chunks, with thread-local output buffers merged at the end. This module
//! provides the same structure on `std::thread::scope`:
//!
//! * [`num_threads`] — effective worker count (env `TSPM_THREADS` override),
//! * [`par_chunks_mut`] — split a mutable slice into contiguous chunks and
//!   process each on its own worker,
//! * [`par_map_chunks`] — map contiguous index ranges to per-thread results
//!   (the "thread-local vector" pattern; caller merges),
//! * [`par_for_each_dynamic`] — dynamic scheduling over an atomic work
//!   counter for irregular per-item cost (e.g. patients with very different
//!   entry counts),
//! * [`par_map_parts`] — map caller-carved parts (e.g. disjoint
//!   `split_at_mut` sub-slices) to one result per part, in part order,
//! * [`Semaphore`] — a counting semaphore (`Mutex` + `Condvar`) for
//!   admission control: bound how many units of work run at once, with a
//!   non-blocking [`Semaphore::try_acquire`] so callers can shed load
//!   instead of queueing (the serving layer's connection limit).
//!
//! All functions degrade to plain sequential execution for 1 thread or tiny
//! inputs, so they are safe to call unconditionally.
//!
//! Synchronization primitives come from the [`crate::sync`] shim, so the
//! semaphore's wait/notify protocol and the dynamic scheduler's claim
//! counter are model-checked exhaustively under `cfg(loom)` (see the
//! `loom_tests` module and the crate-level "Verification" docs). Lock
//! acquisition recovers from poisoning ([`crate::sync::lock_ignore_poison`]):
//! one connection thread panicking while holding the permit lock must not
//! wedge admission control for every later connection.

use crate::sync::atomic::{AtomicUsize, Ordering};
use crate::sync::{lock_ignore_poison, wait_ignore_poison, Condvar, Mutex};

/// Hard ceiling on the worker count, whatever its source. Every worker is
/// a real scoped OS thread, so an env override like `TSPM_THREADS=100000`
/// used to spawn exactly that many threads; any request above this bound
/// is clamped instead.
pub const MAX_THREADS: usize = 512;

/// Effective number of worker threads.
///
/// Priority: explicit `requested` argument (Some>0) → `TSPM_THREADS` env →
/// `std::thread::available_parallelism()`; every source is clamped to
/// [`MAX_THREADS`].
pub fn num_threads(requested: Option<usize>) -> usize {
    resolve_threads(
        requested,
        std::env::var("TSPM_THREADS").ok().as_deref(),
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
    )
}

/// The pure precedence chain behind [`num_threads`], split out so the
/// override logic is testable without mutating the process environment:
/// a positive `requested` wins, else a parseable positive `env` value,
/// else `detected`; the winner is clamped to `[1, MAX_THREADS]`.
pub fn resolve_threads(requested: Option<usize>, env: Option<&str>, detected: usize) -> usize {
    if let Some(n) = requested {
        if n > 0 {
            return n.min(MAX_THREADS);
        }
    }
    if let Some(v) = env {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n > 0 {
                return n.min(MAX_THREADS);
            }
        }
    }
    detected.clamp(1, MAX_THREADS)
}

/// Split `[0, len)` into at most `parts` contiguous ranges of near-equal
/// size. Returns an empty vec for `len == 0`.
pub fn split_ranges(len: usize, parts: usize) -> Vec<std::ops::Range<usize>> {
    if len == 0 || parts == 0 {
        return Vec::new();
    }
    let parts = parts.min(len);
    let base = len / parts;
    let extra = len % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for i in 0..parts {
        let size = base + usize::from(i < extra);
        out.push(start..start + size);
        start += size;
    }
    out
}

/// Process contiguous mutable chunks of `data` in parallel.
///
/// `f(chunk_index, chunk)` runs on a worker thread per chunk; chunk
/// boundaries follow [`split_ranges`].
pub fn par_chunks_mut<T: Send, F>(data: &mut [T], threads: usize, f: F)
where
    F: Fn(usize, &mut [T]) + Sync,
{
    let threads = threads.max(1);
    if threads == 1 || data.len() < 2 {
        if !data.is_empty() {
            f(0, data);
        }
        return;
    }
    let ranges = split_ranges(data.len(), threads);
    // Carve the slice into disjoint mutable chunks up front.
    let mut chunks: Vec<&mut [T]> = Vec::with_capacity(ranges.len());
    let mut rest = data;
    let mut consumed = 0usize;
    for r in &ranges {
        let (head, tail) = rest.split_at_mut(r.end - consumed);
        consumed = r.end;
        chunks.push(head);
        rest = tail;
    }
    std::thread::scope(|s| {
        for (i, chunk) in chunks.into_iter().enumerate() {
            let f = &f;
            s.spawn(move || f(i, chunk));
        }
    });
}

/// Map contiguous index ranges of `[0, len)` to one result per worker.
///
/// This is the paper's "each thread appends to its own vector" pattern:
/// `f(range)` produces a thread-local result (typically a `Vec`), and the
/// per-worker results are returned in range order for the caller to merge.
pub fn par_map_chunks<R, F>(len: usize, threads: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(std::ops::Range<usize>) -> R + Sync,
{
    let threads = threads.max(1);
    let ranges = split_ranges(len, threads);
    if ranges.len() <= 1 {
        return ranges.into_iter().map(&f).collect();
    }
    let n = ranges.len();
    let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
    std::thread::scope(|s| {
        for (slot, range) in slots.iter_mut().zip(ranges) {
            let f = &f;
            s.spawn(move || {
                *slot = Some(f(range));
            });
        }
    });
    slots.into_iter().map(|r| r.expect("worker panicked")).collect()
}

/// Map caller-carved parts to one result each, in part order.
///
/// Where [`par_map_chunks`] splits an index space itself, this variant
/// takes parts the caller already carved — typically disjoint mutable
/// sub-slices from `split_at_mut` paired with their index ranges — and
/// runs `f(part_index, part)` on one worker per part. This is the safe
/// replacement for smuggling a raw base pointer across workers: the
/// borrow checker sees each worker own exactly its slice.
pub fn par_map_parts<T, R, F>(parts: Vec<T>, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, T) -> R + Sync,
{
    if parts.len() <= 1 {
        return parts.into_iter().enumerate().map(|(i, p)| f(i, p)).collect();
    }
    let n = parts.len();
    let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
    std::thread::scope(|s| {
        for ((i, part), slot) in parts.into_iter().enumerate().zip(slots.iter_mut()) {
            let f = &f;
            s.spawn(move || {
                *slot = Some(f(i, part));
            });
        }
    });
    slots.into_iter().map(|r| r.expect("worker panicked")).collect()
}

/// Claim the next block of `[0, len)` from the shared work counter.
///
/// The whole claim protocol of [`par_for_each_dynamic`] lives in this
/// one line so the loom suite can model-check it directly: `fetch_add`
/// hands every caller a distinct `start`, so no item can be claimed
/// twice and none skipped. `Relaxed` suffices — the scope join (or the
/// loom thread join) provides the happens-before edge for the work
/// itself.
fn claim_block(next: &AtomicUsize, len: usize, block: usize) -> Option<std::ops::Range<usize>> {
    let start = next.fetch_add(block, Ordering::Relaxed);
    if start >= len {
        return None;
    }
    Some(start..(start + block).min(len))
}

/// Dynamically scheduled parallel for: items are claimed in blocks of
/// `block` from an atomic counter, so stragglers don't serialize the run.
/// Use when per-item cost is irregular.
pub fn par_for_each_dynamic<F>(len: usize, threads: usize, block: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let threads = threads.max(1);
    let block = block.max(1);
    if threads == 1 || len <= block {
        for i in 0..len {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads.min(len) {
            let next = &next;
            let f = &f;
            s.spawn(move || {
                while let Some(range) = claim_block(next, len, block) {
                    for i in range {
                        f(i);
                    }
                }
            });
        }
    });
}

/// A counting semaphore over `Mutex` + `Condvar`.
///
/// The serving layer uses it as a **connection limit with shedding
/// semantics**: the accept loop calls [`Semaphore::try_acquire`] and
/// turns an exhausted semaphore into an immediate `Busy` response
/// instead of an unbounded queue; graceful shutdown calls the blocking
/// [`Semaphore::acquire`] `permits` times to drain every in-flight
/// holder. Permits are plain counts — releasing a permit that was never
/// acquired is a caller bug and panics in debug builds.
///
/// The permit count is a bare integer kept consistent under one lock, so
/// poison recovery is sound: a holder that panics *while touching the
/// count* can only leave it at a value it fully wrote, and a holder that
/// panics with the permit *checked out* (between `acquire` and `release`)
/// poisons nothing — its permit is simply never returned, which is the
/// shedding behavior the connection limit wants.
pub struct Semaphore {
    permits: Mutex<usize>,
    total: usize,
    cv: Condvar,
}

impl Semaphore {
    /// A semaphore with `permits` initially-available permits.
    pub fn new(permits: usize) -> Semaphore {
        Semaphore { permits: Mutex::new(permits), total: permits, cv: Condvar::new() }
    }

    /// Take a permit without blocking; `false` when none are available.
    pub fn try_acquire(&self) -> bool {
        let mut p = lock_ignore_poison(&self.permits);
        if *p == 0 {
            return false;
        }
        *p -= 1;
        true
    }

    /// Block until a permit is available, then take it.
    pub fn acquire(&self) {
        let mut p = lock_ignore_poison(&self.permits);
        while *p == 0 {
            p = wait_ignore_poison(&self.cv, p);
        }
        *p -= 1;
    }

    /// Return a permit taken by [`Semaphore::acquire`] /
    /// [`Semaphore::try_acquire`].
    pub fn release(&self) {
        let mut p = lock_ignore_poison(&self.permits);
        debug_assert!(*p < self.total, "released a permit that was never acquired");
        *p += 1;
        self.cv.notify_one();
    }

    /// Permits currently available (a racy snapshot — for observability).
    pub fn available(&self) -> usize {
        *lock_ignore_poison(&self.permits)
    }

    /// The permit count the semaphore was built with.
    pub fn total(&self) -> usize {
        self.total
    }
}

// The std tests spawn real OS threads and sleep; under `cfg(loom)` the
// shim's Mutex/Condvar only work inside `loom::model`, so the wall-clock
// suite is compiled out and the exhaustive `loom_tests` suite below
// replaces it.
#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn split_ranges_covers_exactly() {
        for len in [0usize, 1, 2, 5, 97, 100] {
            for parts in [1usize, 2, 3, 7, 16, 200] {
                let ranges = split_ranges(len, parts);
                let total: usize = ranges.iter().map(|r| r.len()).sum();
                assert_eq!(total, len, "len={len} parts={parts}");
                // contiguous & non-overlapping
                let mut expect = 0;
                for r in &ranges {
                    assert_eq!(r.start, expect);
                    assert!(!r.is_empty());
                    expect = r.end;
                }
                if len > 0 {
                    assert_eq!(expect, len);
                    // near-equal: sizes differ by at most 1
                    let min = ranges.iter().map(|r| r.len()).min().unwrap();
                    let max = ranges.iter().map(|r| r.len()).max().unwrap();
                    assert!(max - min <= 1);
                }
            }
        }
    }

    #[test]
    fn par_chunks_mut_touches_every_item_once() {
        for threads in [1usize, 2, 4, 8] {
            let mut data = vec![0u32; 1000];
            par_chunks_mut(&mut data, threads, |_, chunk| {
                for v in chunk {
                    *v += 1;
                }
            });
            assert!(data.iter().all(|&v| v == 1), "threads={threads}");
        }
    }

    #[test]
    fn par_chunks_mut_chunk_indices_are_ordered() {
        let mut data = vec![0usize; 64];
        par_chunks_mut(&mut data, 4, |ci, chunk| {
            for v in chunk {
                *v = ci;
            }
        });
        // chunk indices must be non-decreasing across the slice
        for w in data.windows(2) {
            assert!(w[0] <= w[1]);
        }
    }

    #[test]
    fn par_map_chunks_merges_in_order() {
        let results = par_map_chunks(100, 4, |r| r.clone().collect::<Vec<usize>>());
        let merged: Vec<usize> = results.into_iter().flatten().collect();
        assert_eq!(merged, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn par_map_chunks_empty() {
        let results: Vec<Vec<usize>> = par_map_chunks(0, 4, |r| r.collect());
        assert!(results.is_empty());
    }

    #[test]
    fn par_for_each_dynamic_visits_all_once() {
        let counters: Vec<AtomicU64> = (0..500).map(|_| AtomicU64::new(0)).collect();
        par_for_each_dynamic(500, 4, 7, |i| {
            counters[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(counters.iter().all(|c| c.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn num_threads_request_wins() {
        assert_eq!(num_threads(Some(3)), 3);
        assert!(num_threads(None) >= 1);
        assert!(num_threads(None) <= MAX_THREADS);
    }

    #[test]
    fn resolve_threads_precedence_chain() {
        // explicit request beats env and detection
        assert_eq!(resolve_threads(Some(3), Some("7"), 16), 3);
        // request of 0 means "unset" → env wins
        assert_eq!(resolve_threads(Some(0), Some("7"), 16), 7);
        assert_eq!(resolve_threads(None, Some("7"), 16), 7);
        // whitespace is tolerated
        assert_eq!(resolve_threads(None, Some(" 5 "), 16), 5);
        // unparseable / non-positive env falls through to detection
        assert_eq!(resolve_threads(None, Some("lots"), 16), 16);
        assert_eq!(resolve_threads(None, Some("0"), 16), 16);
        assert_eq!(resolve_threads(None, Some("-2"), 16), 16);
        assert_eq!(resolve_threads(None, None, 16), 16);
    }

    #[test]
    fn semaphore_try_acquire_sheds_at_the_limit() {
        let s = Semaphore::new(2);
        assert_eq!(s.available(), 2);
        assert!(s.try_acquire());
        assert!(s.try_acquire());
        assert!(!s.try_acquire(), "no third permit");
        s.release();
        assert!(s.try_acquire());
        s.release();
        s.release();
        assert_eq!(s.available(), 2);
        assert_eq!(s.total(), 2);
    }

    #[test]
    fn semaphore_acquire_blocks_until_release() {
        let s = Semaphore::new(1);
        assert!(s.try_acquire());
        let turn = AtomicU64::new(0);
        std::thread::scope(|scope| {
            scope.spawn(|| {
                s.acquire(); // blocks until the main thread releases
                assert_eq!(turn.load(Ordering::SeqCst), 1, "acquired before release");
                s.release();
            });
            std::thread::sleep(std::time::Duration::from_millis(20));
            turn.store(1, Ordering::SeqCst);
            s.release();
        });
        assert_eq!(s.available(), 1);
    }

    #[test]
    fn semaphore_drain_by_acquiring_all_permits() {
        // The graceful-shutdown pattern: acquire total() permits to wait
        // for every in-flight holder.
        let s = Semaphore::new(3);
        assert!(s.try_acquire());
        std::thread::scope(|scope| {
            scope.spawn(|| {
                std::thread::sleep(std::time::Duration::from_millis(20));
                s.release(); // the in-flight holder finishes
            });
            for _ in 0..s.total() {
                s.acquire();
            }
            assert_eq!(s.available(), 0, "drained: all permits held here");
        });
    }

    #[test]
    fn resolve_threads_clamps_every_source() {
        // the regression: TSPM_THREADS=100000 must not mean 100000 threads
        assert_eq!(resolve_threads(None, Some("100000"), 8), MAX_THREADS);
        assert_eq!(resolve_threads(Some(usize::MAX), None, 8), MAX_THREADS);
        assert_eq!(resolve_threads(None, None, usize::MAX), MAX_THREADS);
        // and a detection failure still yields at least one worker
        assert_eq!(resolve_threads(None, None, 0), 1);
    }

    #[test]
    fn par_map_parts_preserves_part_order() {
        let mut data: Vec<u32> = (0..100).collect();
        let ranges = split_ranges(data.len(), 4);
        // Carve disjoint mutable sub-slices the way sparsity does.
        let mut parts: Vec<&mut [u32]> = Vec::new();
        let mut rest: &mut [u32] = &mut data;
        let mut consumed = 0usize;
        for r in &ranges {
            let (head, tail) = rest.split_at_mut(r.end - consumed);
            consumed = r.end;
            parts.push(head);
            rest = tail;
        }
        let sums = par_map_parts(parts, |i, part| {
            for v in part.iter_mut() {
                *v += 1;
            }
            (i, part.iter().map(|&v| v as u64).sum::<u64>())
        });
        // results come back in part order, every element touched once
        assert_eq!(sums.iter().map(|&(i, _)| i).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
        assert_eq!(sums.iter().map(|&(_, s)| s).sum::<u64>(), (1..=100).sum::<u64>());
        assert_eq!(data, (1..=100).collect::<Vec<u32>>());
    }

    #[test]
    fn semaphore_survives_a_panicking_permit_lock_holder() {
        // One connection thread panicking while *holding the permit lock*
        // must not wedge admission control: later acquire/release calls
        // recover the guard from the poisoned mutex.
        let s = Semaphore::new(2);
        let res = std::thread::scope(|scope| {
            scope
                .spawn(|| {
                    let _g = s.permits.lock().unwrap();
                    panic!("connection thread dies holding the permit lock");
                })
                .join()
        });
        assert!(res.is_err(), "the holder did panic");
        assert!(s.permits.lock().is_err(), "the permit lock is poisoned");
        // Admission control still works end-to-end.
        assert!(s.try_acquire());
        assert!(s.try_acquire());
        assert!(!s.try_acquire(), "limit still enforced after poisoning");
        s.release();
        s.acquire();
        s.release();
        s.release();
        assert_eq!(s.available(), 2);
    }
}

/// Exhaustive-interleaving model checks for the two protocols this
/// module owns: the semaphore's wait/notify permit accounting and the
/// dynamic scheduler's atomic claim counter. Compiled only under
/// `RUSTFLAGS="--cfg loom"`; see the crate-level "Verification" docs for
/// the run command.
#[cfg(all(test, loom))]
mod loom_tests {
    use super::*;
    use crate::sync::Arc;

    /// Every schedule of two contenders over one permit ends with the
    /// permit returned and nobody lost a wakeup (loom itself fails the
    /// test on any schedule where a blocked `acquire` is never woken —
    /// that schedule simply cannot terminate).
    #[test]
    fn loom_semaphore_no_lost_wakeups() {
        loom::model(|| {
            let s = Arc::new(Semaphore::new(1));
            let a = {
                let s = Arc::clone(&s);
                loom::thread::spawn(move || {
                    s.acquire();
                    s.release();
                })
            };
            let b = {
                let s = Arc::clone(&s);
                loom::thread::spawn(move || {
                    s.acquire();
                    s.release();
                })
            };
            a.join().unwrap();
            b.join().unwrap();
            assert_eq!(s.available(), 1, "permit returned on every schedule");
        });
    }

    /// Shedding accounting: with one permit and two `try_acquire`
    /// contenders, no schedule admits both before a release.
    #[test]
    fn loom_semaphore_try_acquire_never_overadmits() {
        loom::model(|| {
            let s = Arc::new(Semaphore::new(1));
            let admitted = Arc::new(crate::sync::atomic::AtomicUsize::new(0));
            let mut handles = Vec::new();
            for _ in 0..2 {
                let s = Arc::clone(&s);
                let admitted = Arc::clone(&admitted);
                handles.push(loom::thread::spawn(move || {
                    if s.try_acquire() {
                        admitted.fetch_add(1, Ordering::Relaxed);
                    }
                }));
            }
            for h in handles {
                h.join().unwrap();
            }
            let n = admitted.load(Ordering::Relaxed);
            assert_eq!(n, 1, "exactly one contender admitted, one shed");
            assert_eq!(s.available(), 0, "the admitted permit is checked out");
        });
    }

    /// The dynamic scheduler's claim counter: on every schedule of two
    /// workers over three one-item blocks, each item is claimed exactly
    /// once — no double-claimed work, none skipped.
    #[test]
    fn loom_claim_block_no_double_claims() {
        loom::model(|| {
            const LEN: usize = 3;
            let next = Arc::new(AtomicUsize::new(0));
            let claims: Arc<Vec<crate::sync::atomic::AtomicUsize>> =
                Arc::new((0..LEN).map(|_| crate::sync::atomic::AtomicUsize::new(0)).collect());
            let mut handles = Vec::new();
            for _ in 0..2 {
                let next = Arc::clone(&next);
                let claims = Arc::clone(&claims);
                handles.push(loom::thread::spawn(move || {
                    while let Some(range) = claim_block(&next, LEN, 1) {
                        for i in range {
                            claims[i].fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }));
            }
            for h in handles {
                h.join().unwrap();
            }
            for (i, c) in claims.iter().enumerate() {
                assert_eq!(c.load(Ordering::Relaxed), 1, "item {i} claimed exactly once");
            }
        });
    }
}
