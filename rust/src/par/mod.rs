//! Scoped-thread parallel-map substrate — the OpenMP stand-in.
//!
//! The paper parallelizes tSPM+ with OpenMP `parallel for` over patient
//! chunks, with thread-local output buffers merged at the end. This module
//! provides the same structure on `std::thread::scope`:
//!
//! * [`num_threads`] — effective worker count (env `TSPM_THREADS` override),
//! * [`par_chunks_mut`] — split a mutable slice into contiguous chunks and
//!   process each on its own worker,
//! * [`par_map_chunks`] — map contiguous index ranges to per-thread results
//!   (the "thread-local vector" pattern; caller merges),
//! * [`par_for_each_dynamic`] — dynamic scheduling over an atomic work
//!   counter for irregular per-item cost (e.g. patients with very different
//!   entry counts),
//! * [`Semaphore`] — a counting semaphore (`Mutex` + `Condvar`) for
//!   admission control: bound how many units of work run at once, with a
//!   non-blocking [`Semaphore::try_acquire`] so callers can shed load
//!   instead of queueing (the serving layer's connection limit).
//!
//! All functions degrade to plain sequential execution for 1 thread or tiny
//! inputs, so they are safe to call unconditionally.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};

/// Hard ceiling on the worker count, whatever its source. Every worker is
/// a real scoped OS thread, so an env override like `TSPM_THREADS=100000`
/// used to spawn exactly that many threads; any request above this bound
/// is clamped instead.
pub const MAX_THREADS: usize = 512;

/// Effective number of worker threads.
///
/// Priority: explicit `requested` argument (Some>0) → `TSPM_THREADS` env →
/// `std::thread::available_parallelism()`; every source is clamped to
/// [`MAX_THREADS`].
pub fn num_threads(requested: Option<usize>) -> usize {
    resolve_threads(
        requested,
        std::env::var("TSPM_THREADS").ok().as_deref(),
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
    )
}

/// The pure precedence chain behind [`num_threads`], split out so the
/// override logic is testable without mutating the process environment:
/// a positive `requested` wins, else a parseable positive `env` value,
/// else `detected`; the winner is clamped to `[1, MAX_THREADS]`.
pub fn resolve_threads(requested: Option<usize>, env: Option<&str>, detected: usize) -> usize {
    if let Some(n) = requested {
        if n > 0 {
            return n.min(MAX_THREADS);
        }
    }
    if let Some(v) = env {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n > 0 {
                return n.min(MAX_THREADS);
            }
        }
    }
    detected.clamp(1, MAX_THREADS)
}

/// Split `[0, len)` into at most `parts` contiguous ranges of near-equal
/// size. Returns an empty vec for `len == 0`.
pub fn split_ranges(len: usize, parts: usize) -> Vec<std::ops::Range<usize>> {
    if len == 0 || parts == 0 {
        return Vec::new();
    }
    let parts = parts.min(len);
    let base = len / parts;
    let extra = len % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for i in 0..parts {
        let size = base + usize::from(i < extra);
        out.push(start..start + size);
        start += size;
    }
    out
}

/// Process contiguous mutable chunks of `data` in parallel.
///
/// `f(chunk_index, chunk)` runs on a worker thread per chunk; chunk
/// boundaries follow [`split_ranges`].
pub fn par_chunks_mut<T: Send, F>(data: &mut [T], threads: usize, f: F)
where
    F: Fn(usize, &mut [T]) + Sync,
{
    let threads = threads.max(1);
    if threads == 1 || data.len() < 2 {
        if !data.is_empty() {
            f(0, data);
        }
        return;
    }
    let ranges = split_ranges(data.len(), threads);
    // Carve the slice into disjoint mutable chunks up front.
    let mut chunks: Vec<&mut [T]> = Vec::with_capacity(ranges.len());
    let mut rest = data;
    let mut consumed = 0usize;
    for r in &ranges {
        let (head, tail) = rest.split_at_mut(r.end - consumed);
        consumed = r.end;
        chunks.push(head);
        rest = tail;
    }
    std::thread::scope(|s| {
        for (i, chunk) in chunks.into_iter().enumerate() {
            let f = &f;
            s.spawn(move || f(i, chunk));
        }
    });
}

/// Map contiguous index ranges of `[0, len)` to one result per worker.
///
/// This is the paper's "each thread appends to its own vector" pattern:
/// `f(range)` produces a thread-local result (typically a `Vec`), and the
/// per-worker results are returned in range order for the caller to merge.
pub fn par_map_chunks<R, F>(len: usize, threads: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(std::ops::Range<usize>) -> R + Sync,
{
    let threads = threads.max(1);
    let ranges = split_ranges(len, threads);
    if ranges.len() <= 1 {
        return ranges.into_iter().map(&f).collect();
    }
    let n = ranges.len();
    let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
    std::thread::scope(|s| {
        for (slot, range) in slots.iter_mut().zip(ranges) {
            let f = &f;
            s.spawn(move || {
                *slot = Some(f(range));
            });
        }
    });
    slots.into_iter().map(|r| r.expect("worker panicked")).collect()
}

/// Dynamically scheduled parallel for: items are claimed in blocks of
/// `block` from an atomic counter, so stragglers don't serialize the run.
/// Use when per-item cost is irregular.
pub fn par_for_each_dynamic<F>(len: usize, threads: usize, block: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let threads = threads.max(1);
    let block = block.max(1);
    if threads == 1 || len <= block {
        for i in 0..len {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads.min(len) {
            let next = &next;
            let f = &f;
            s.spawn(move || loop {
                let start = next.fetch_add(block, Ordering::Relaxed);
                if start >= len {
                    break;
                }
                let end = (start + block).min(len);
                for i in start..end {
                    f(i);
                }
            });
        }
    });
}

/// A counting semaphore over `Mutex` + `Condvar`.
///
/// The serving layer uses it as a **connection limit with shedding
/// semantics**: the accept loop calls [`Semaphore::try_acquire`] and
/// turns an exhausted semaphore into an immediate `Busy` response
/// instead of an unbounded queue; graceful shutdown calls the blocking
/// [`Semaphore::acquire`] `permits` times to drain every in-flight
/// holder. Permits are plain counts — releasing a permit that was never
/// acquired is a caller bug and panics in debug builds.
pub struct Semaphore {
    permits: Mutex<usize>,
    total: usize,
    cv: Condvar,
}

impl Semaphore {
    /// A semaphore with `permits` initially-available permits.
    pub fn new(permits: usize) -> Semaphore {
        Semaphore { permits: Mutex::new(permits), total: permits, cv: Condvar::new() }
    }

    /// Take a permit without blocking; `false` when none are available.
    pub fn try_acquire(&self) -> bool {
        let mut p = self.permits.lock().unwrap();
        if *p == 0 {
            return false;
        }
        *p -= 1;
        true
    }

    /// Block until a permit is available, then take it.
    pub fn acquire(&self) {
        let mut p = self.permits.lock().unwrap();
        while *p == 0 {
            p = self.cv.wait(p).unwrap();
        }
        *p -= 1;
    }

    /// Return a permit taken by [`Semaphore::acquire`] /
    /// [`Semaphore::try_acquire`].
    pub fn release(&self) {
        let mut p = self.permits.lock().unwrap();
        debug_assert!(*p < self.total, "released a permit that was never acquired");
        *p += 1;
        self.cv.notify_one();
    }

    /// Permits currently available (a racy snapshot — for observability).
    pub fn available(&self) -> usize {
        *self.permits.lock().unwrap()
    }

    /// The permit count the semaphore was built with.
    pub fn total(&self) -> usize {
        self.total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn split_ranges_covers_exactly() {
        for len in [0usize, 1, 2, 5, 97, 100] {
            for parts in [1usize, 2, 3, 7, 16, 200] {
                let ranges = split_ranges(len, parts);
                let total: usize = ranges.iter().map(|r| r.len()).sum();
                assert_eq!(total, len, "len={len} parts={parts}");
                // contiguous & non-overlapping
                let mut expect = 0;
                for r in &ranges {
                    assert_eq!(r.start, expect);
                    assert!(!r.is_empty());
                    expect = r.end;
                }
                if len > 0 {
                    assert_eq!(expect, len);
                    // near-equal: sizes differ by at most 1
                    let min = ranges.iter().map(|r| r.len()).min().unwrap();
                    let max = ranges.iter().map(|r| r.len()).max().unwrap();
                    assert!(max - min <= 1);
                }
            }
        }
    }

    #[test]
    fn par_chunks_mut_touches_every_item_once() {
        for threads in [1usize, 2, 4, 8] {
            let mut data = vec![0u32; 1000];
            par_chunks_mut(&mut data, threads, |_, chunk| {
                for v in chunk {
                    *v += 1;
                }
            });
            assert!(data.iter().all(|&v| v == 1), "threads={threads}");
        }
    }

    #[test]
    fn par_chunks_mut_chunk_indices_are_ordered() {
        let mut data = vec![0usize; 64];
        par_chunks_mut(&mut data, 4, |ci, chunk| {
            for v in chunk {
                *v = ci;
            }
        });
        // chunk indices must be non-decreasing across the slice
        for w in data.windows(2) {
            assert!(w[0] <= w[1]);
        }
    }

    #[test]
    fn par_map_chunks_merges_in_order() {
        let results = par_map_chunks(100, 4, |r| r.clone().collect::<Vec<usize>>());
        let merged: Vec<usize> = results.into_iter().flatten().collect();
        assert_eq!(merged, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn par_map_chunks_empty() {
        let results: Vec<Vec<usize>> = par_map_chunks(0, 4, |r| r.collect());
        assert!(results.is_empty());
    }

    #[test]
    fn par_for_each_dynamic_visits_all_once() {
        let counters: Vec<AtomicU64> = (0..500).map(|_| AtomicU64::new(0)).collect();
        par_for_each_dynamic(500, 4, 7, |i| {
            counters[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(counters.iter().all(|c| c.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn num_threads_request_wins() {
        assert_eq!(num_threads(Some(3)), 3);
        assert!(num_threads(None) >= 1);
        assert!(num_threads(None) <= MAX_THREADS);
    }

    #[test]
    fn resolve_threads_precedence_chain() {
        // explicit request beats env and detection
        assert_eq!(resolve_threads(Some(3), Some("7"), 16), 3);
        // request of 0 means "unset" → env wins
        assert_eq!(resolve_threads(Some(0), Some("7"), 16), 7);
        assert_eq!(resolve_threads(None, Some("7"), 16), 7);
        // whitespace is tolerated
        assert_eq!(resolve_threads(None, Some(" 5 "), 16), 5);
        // unparseable / non-positive env falls through to detection
        assert_eq!(resolve_threads(None, Some("lots"), 16), 16);
        assert_eq!(resolve_threads(None, Some("0"), 16), 16);
        assert_eq!(resolve_threads(None, Some("-2"), 16), 16);
        assert_eq!(resolve_threads(None, None, 16), 16);
    }

    #[test]
    fn semaphore_try_acquire_sheds_at_the_limit() {
        let s = Semaphore::new(2);
        assert_eq!(s.available(), 2);
        assert!(s.try_acquire());
        assert!(s.try_acquire());
        assert!(!s.try_acquire(), "no third permit");
        s.release();
        assert!(s.try_acquire());
        s.release();
        s.release();
        assert_eq!(s.available(), 2);
        assert_eq!(s.total(), 2);
    }

    #[test]
    fn semaphore_acquire_blocks_until_release() {
        let s = Semaphore::new(1);
        assert!(s.try_acquire());
        let turn = AtomicU64::new(0);
        std::thread::scope(|scope| {
            scope.spawn(|| {
                s.acquire(); // blocks until the main thread releases
                assert_eq!(turn.load(Ordering::SeqCst), 1, "acquired before release");
                s.release();
            });
            std::thread::sleep(std::time::Duration::from_millis(20));
            turn.store(1, Ordering::SeqCst);
            s.release();
        });
        assert_eq!(s.available(), 1);
    }

    #[test]
    fn semaphore_drain_by_acquiring_all_permits() {
        // The graceful-shutdown pattern: acquire total() permits to wait
        // for every in-flight holder.
        let s = Semaphore::new(3);
        assert!(s.try_acquire());
        std::thread::scope(|scope| {
            scope.spawn(|| {
                std::thread::sleep(std::time::Duration::from_millis(20));
                s.release(); // the in-flight holder finishes
            });
            for _ in 0..s.total() {
                s.acquire();
            }
            assert_eq!(s.available(), 0, "drained: all permits held here");
        });
    }

    #[test]
    fn resolve_threads_clamps_every_source() {
        // the regression: TSPM_THREADS=100000 must not mean 100000 threads
        assert_eq!(resolve_threads(None, Some("100000"), 8), MAX_THREADS);
        assert_eq!(resolve_threads(Some(usize::MAX), None, 8), MAX_THREADS);
        assert_eq!(resolve_threads(None, None, usize::MAX), MAX_THREADS);
        // and a detection failure still yields at least one worker
        assert_eq!(resolve_threads(None, None, 0), 1);
    }
}
