//! Parallel in-place samplesort substrate — the ips4o stand-in.
//!
//! The paper leans on Axtmann et al.'s in-place parallel super-scalar
//! samplesort (ips4o) for its two big sorts: dbmart by `(patient, date)`
//! before mining, and mined sequences by sequence id before sparsity
//! screening. The offline registry has no sorting crate, so this module
//! implements the same algorithmic family from scratch:
//!
//! * a sequential **introsort** ([`seq_sort_by_key`]): median-of-three
//!   quicksort, insertion sort below a small threshold, heapsort at the
//!   depth limit — the base case of the parallel sort;
//! * a **parallel samplesort** ([`par_sort_by_key`]): oversampled splitter
//!   selection, a parallel classification histogram, an in-place
//!   American-flag cycle permutation into buckets, and parallel recursion
//!   over buckets with dynamic scheduling.
//!
//! The permutation pass is sequential O(n) swaps (ips4o parallelizes it
//! with block trading; on this 1-core testbed that refinement cannot be
//! observed, see DESIGN.md §Substitutions). Everything else — histogram
//! and per-bucket recursion — runs on the worker pool.

use crate::par;

/// Below this length we always use insertion sort.
const INSERTION_THRESHOLD: usize = 24;

/// Below this length the parallel sort falls through to sequential.
const SEQ_THRESHOLD: usize = 1 << 13;

/// Oversampling factor for splitter selection.
const OVERSAMPLE: usize = 16;

/// Maximum bucket fanout per recursion level.
const MAX_BUCKETS: usize = 64;

// ---------------------------------------------------------------------------
// Sequential introsort
// ---------------------------------------------------------------------------

/// Sort `items` by the key extracted by `key`, sequentially (introsort).
pub fn seq_sort_by_key<T, K, F>(items: &mut [T], key: F)
where
    K: Ord,
    F: Fn(&T) -> K + Copy,
{
    let len = items.len();
    if len < 2 {
        return;
    }
    let depth_limit = 2 * (usize::BITS - len.leading_zeros()) as usize;
    introsort(items, key, depth_limit);
}

fn introsort<T, K, F>(items: &mut [T], key: F, depth: usize)
where
    K: Ord,
    F: Fn(&T) -> K + Copy,
{
    let mut items = items;
    let mut depth = depth;
    loop {
        let len = items.len();
        if len <= INSERTION_THRESHOLD {
            insertion_sort(items, key);
            return;
        }
        if depth == 0 {
            heapsort(items, key);
            return;
        }
        depth -= 1;
        let p = partition_mo3(items, key);
        // Recurse on the smaller side, loop on the larger (O(log n) stack).
        let (lo, hi) = items.split_at_mut(p);
        let hi = &mut hi[1..];
        if lo.len() < hi.len() {
            introsort(lo, key, depth);
            items = hi;
        } else {
            introsort(hi, key, depth);
            items = lo;
        }
    }
}

fn insertion_sort<T, K, F>(items: &mut [T], key: F)
where
    K: Ord,
    F: Fn(&T) -> K + Copy,
{
    for i in 1..items.len() {
        let mut j = i;
        while j > 0 && key(&items[j - 1]) > key(&items[j]) {
            items.swap(j - 1, j);
            j -= 1;
        }
    }
}

/// Hoare-style partition with median-of-three pivot. Returns the final
/// pivot index; elements `< pivot` are left of it, `>= pivot` right.
fn partition_mo3<T, K, F>(items: &mut [T], key: F) -> usize
where
    K: Ord,
    F: Fn(&T) -> K + Copy,
{
    let len = items.len();
    let mid = len / 2;
    // median-of-three to items[len-1]
    if key(&items[0]) > key(&items[mid]) {
        items.swap(0, mid);
    }
    if key(&items[0]) > key(&items[len - 1]) {
        items.swap(0, len - 1);
    }
    if key(&items[mid]) > key(&items[len - 1]) {
        items.swap(mid, len - 1);
    }
    items.swap(mid, len - 1); // pivot at end
    let mut store = 0;
    for i in 0..len - 1 {
        if key(&items[i]) < key(&items[len - 1]) {
            items.swap(i, store);
            store += 1;
        }
    }
    items.swap(store, len - 1);
    store
}

fn heapsort<T, K, F>(items: &mut [T], key: F)
where
    K: Ord,
    F: Fn(&T) -> K + Copy,
{
    let len = items.len();
    for start in (0..len / 2).rev() {
        sift_down(items, key, start, len);
    }
    for end in (1..len).rev() {
        items.swap(0, end);
        sift_down(items, key, 0, end);
    }
}

fn sift_down<T, K, F>(items: &mut [T], key: F, mut root: usize, end: usize)
where
    K: Ord,
    F: Fn(&T) -> K + Copy,
{
    loop {
        let mut child = 2 * root + 1;
        if child >= end {
            return;
        }
        if child + 1 < end && key(&items[child]) < key(&items[child + 1]) {
            child += 1;
        }
        if key(&items[root]) >= key(&items[child]) {
            return;
        }
        items.swap(root, child);
        root = child;
    }
}

// ---------------------------------------------------------------------------
// Parallel samplesort
// ---------------------------------------------------------------------------

/// Sort `items` by key on up to `threads` workers (parallel samplesort).
///
/// Falls back to [`seq_sort_by_key`] for small inputs or `threads <= 1`.
pub fn par_sort_by_key<T, K, F>(items: &mut [T], key: F, threads: usize)
where
    T: Send + Sync,
    K: Ord + Copy + Send + Sync,
    F: Fn(&T) -> K + Copy + Send + Sync,
{
    let threads = threads.max(1);
    if threads == 1 || items.len() < SEQ_THRESHOLD {
        seq_sort_by_key(items, key);
        return;
    }
    samplesort_recurse(items, key, threads);
}

fn samplesort_recurse<T, K, F>(items: &mut [T], key: F, threads: usize)
where
    T: Send + Sync,
    K: Ord + Copy + Send + Sync,
    F: Fn(&T) -> K + Copy + Send + Sync,
{
    let len = items.len();
    if len < SEQ_THRESHOLD {
        seq_sort_by_key(items, key);
        return;
    }

    // 1. Splitter selection: sort an oversample, take every OVERSAMPLE-th.
    let nbuckets = (threads * 4).next_power_of_two().min(MAX_BUCKETS).max(2);
    let sample_size = (nbuckets * OVERSAMPLE).min(len);
    let mut sample: Vec<K> = Vec::with_capacity(sample_size);
    let stride = len / sample_size;
    for i in 0..sample_size {
        sample.push(key(&items[i * stride]));
    }
    sample.sort_unstable();
    let mut splitters: Vec<K> = Vec::with_capacity(nbuckets - 1);
    for b in 1..nbuckets {
        splitters.push(sample[b * sample.len() / nbuckets]);
    }
    splitters.dedup();
    if splitters.is_empty() {
        // All sampled keys equal — likely highly duplicated input; the
        // sequential sort handles it without degenerate recursion.
        seq_sort_by_key(items, key);
        return;
    }
    let nb = splitters.len() + 1;

    // 2. Parallel classification histogram.
    let bucket_of = |k: &K| -> usize {
        // first splitter > k  ⇒  bucket index (partition point)
        let mut lo = 0usize;
        let mut hi = splitters.len();
        while lo < hi {
            let mid = (lo + hi) / 2;
            if *k <= splitters[mid] {
                hi = mid;
            } else {
                lo = mid + 1;
            }
        }
        lo
    };
    let items_ro: &[T] = items;
    let histograms: Vec<Vec<usize>> = par::par_map_chunks(len, threads, |range| {
        let mut h = vec![0usize; nb];
        for item in &items_ro[range] {
            h[bucket_of(&key(item))] += 1;
        }
        h
    });
    let mut counts = vec![0usize; nb];
    for h in &histograms {
        for (c, v) in counts.iter_mut().zip(h) {
            *c += v;
        }
    }

    // Degenerate distribution (one bucket holds everything): no progress
    // possible through splitting, finish sequentially.
    if counts.iter().any(|&c| c == len) {
        seq_sort_by_key(items, key);
        return;
    }

    // 3. In-place American-flag permutation into bucket regions.
    let mut starts = vec![0usize; nb + 1];
    for b in 0..nb {
        starts[b + 1] = starts[b] + counts[b];
    }
    let mut write = starts[..nb].to_vec(); // next write slot per bucket
    let ends = &starts[1..];
    for b in 0..nb {
        while write[b] < ends[b] {
            let mut idx = write[b];
            let mut target = bucket_of(&key(&items[idx]));
            while target != b {
                items.swap(idx, write[target]);
                write[target] += 1;
                idx = write[b];
                target = bucket_of(&key(&items[idx]));
            }
            write[b] += 1;
        }
    }

    // 4. Recurse per bucket in parallel (dynamic scheduling: bucket sizes
    //    are irregular).
    let mut buckets: Vec<&mut [T]> = Vec::with_capacity(nb);
    let mut rest = items;
    let mut consumed = 0usize;
    for b in 0..nb {
        let (head, tail) = rest.split_at_mut(starts[b + 1] - consumed);
        consumed = starts[b + 1];
        buckets.push(head);
        rest = tail;
    }
    let work: Vec<std::sync::Mutex<Option<&mut [T]>>> =
        buckets.into_iter().map(|b| std::sync::Mutex::new(Some(b))).collect();
    let next = std::sync::atomic::AtomicUsize::new(0);
    std::thread::scope(|s| {
        let work = &work;
        for _ in 0..threads.min(nb) {
            let next = &next;
            s.spawn(move || loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= work.len() {
                    break;
                }
                let bucket = work[i].lock().unwrap().take();
                if let Some(bucket) = bucket {
                    // Nested parallelism is counter-productive once the
                    // data is split; each bucket sorts sequentially.
                    seq_sort_by_key(bucket, key);
                }
            });
        }
    });
}

/// Convenience: check whether a slice is sorted by `key`.
pub fn is_sorted_by_key<T, K: Ord, F: Fn(&T) -> K>(items: &[T], key: F) -> bool {
    items.windows(2).all(|w| key(&w[0]) <= key(&w[1]))
}

/// Production sort entry point with an adaptive policy (perf pass,
/// EXPERIMENTS.md §Perf): on a single worker the standard library's
/// pdqsort wins (measured 2.7 s vs 5.0 s radix vs 16 s samplesort-based
/// pipeline on 46 M 16-byte records); with real parallelism the
/// distribution sorts win because pdqsort is single-threaded. The engine
/// hot paths call this and get the right algorithm either way.
pub fn sort_auto<T, F>(items: &mut [T], key: F, threads: usize)
where
    T: Send + Sync,
    F: Fn(&T) -> u128 + Copy + Send + Sync,
{
    if threads <= 1 {
        items.sort_unstable_by_key(key);
    } else {
        par_sort_by_radix_key(items, key, threads);
    }
}

// ---------------------------------------------------------------------------
// MSD radix sort for integer keys (perf pass, EXPERIMENTS.md §Perf)
// ---------------------------------------------------------------------------

/// Below this length radix recursion falls through to insertion sort.
const RADIX_BASE: usize = 96;

/// Sort by an integer key (≤ 128 bits) with an in-place MSD radix sort:
/// 256-way American-flag passes over successive key bytes, skipping the
/// shared-prefix bytes (computed from the min/max key), recursing until
/// [`RADIX_BASE`] then insertion-sorting. For the engine's u32/u64/u128
/// composite keys this is ~3–5× faster than the comparison samplesort —
/// the classify step is a shift+mask instead of a splitter binary search.
///
/// Parallelism: the top-level pass histograms in parallel and the
/// per-bucket recursion is distributed over the worker pool.
pub fn par_sort_by_radix_key<T, F>(items: &mut [T], key: F, threads: usize)
where
    T: Send + Sync,
    F: Fn(&T) -> u128 + Copy + Send + Sync,
{
    let threads = threads.max(1);
    radix_pass(items, key, threads);
}

fn min_max_key<T, F>(items: &[T], key: F, threads: usize) -> (u128, u128)
where
    T: Send + Sync,
    F: Fn(&T) -> u128 + Copy + Send + Sync,
{
    let ranges = par::par_map_chunks(items.len(), threads, |range| {
        let mut lo = u128::MAX;
        let mut hi = 0u128;
        for item in &items[range] {
            let k = key(item);
            lo = lo.min(k);
            hi = hi.max(k);
        }
        (lo, hi)
    });
    ranges
        .into_iter()
        .fold((u128::MAX, 0), |(lo, hi), (l, h)| (lo.min(l), hi.max(h)))
}

#[inline]
fn byte_at(k: u128, level: usize) -> usize {
    debug_assert!(level < 16);
    ((k >> (120 - 8 * level)) & 0xFF) as usize
}

fn radix_pass<T, F>(items: &mut [T], key: F, threads: usize)
where
    T: Send + Sync,
    F: Fn(&T) -> u128 + Copy + Send + Sync,
{
    let len = items.len();
    if len < RADIX_BASE {
        seq_sort_by_key(items, key);
        return;
    }

    // Shared-prefix elimination at EVERY level: one min/max scan jumps
    // straight to the first differing byte, so constant key bytes
    // (zero-padded patient ids, date sign bytes…) never cost a
    // histogram+permute pass.
    let (min, max) = min_max_key(items, key, threads);
    if min == max {
        return; // all keys equal
    }
    let level = ((min ^ max).leading_zeros() / 8) as usize; // 0 = MSB

    // Histogram (parallel at large sizes).
    let mut counts = [0usize; 256];
    if len >= SEQ_THRESHOLD && threads > 1 {
        let partials = par::par_map_chunks(len, threads, |range| {
            let mut h = [0usize; 256];
            for item in &items[range] {
                h[byte_at(key(item), level)] += 1;
            }
            h
        });
        for h in partials {
            for (c, v) in counts.iter_mut().zip(h.iter()) {
                *c += v;
            }
        }
    } else {
        for item in items.iter() {
            counts[byte_at(key(item), level)] += 1;
        }
    }

    // American-flag in-place permutation.
    let mut starts = [0usize; 257];
    for b in 0..256 {
        starts[b + 1] = starts[b] + counts[b];
    }
    let mut write = starts;
    for b in 0..256 {
        let end = starts[b + 1];
        while write[b] < end {
            let idx = write[b];
            let mut target = byte_at(key(&items[idx]), level);
            while target != b {
                items.swap(idx, write[target]);
                write[target] += 1;
                target = byte_at(key(&items[idx]), level);
            }
            write[b] += 1;
        }
    }

    // Recurse per bucket; parallel dynamic scheduling at the top.
    let mut buckets: Vec<&mut [T]> = Vec::with_capacity(256);
    let mut rest = items;
    let mut consumed = 0usize;
    for b in 0..256 {
        let (head, tail) = rest.split_at_mut(starts[b + 1] - consumed);
        consumed = starts[b + 1];
        if head.len() > 1 {
            buckets.push(head);
        }
        rest = tail;
    }
    if threads == 1 || buckets.len() <= 1 {
        for bucket in buckets {
            radix_pass(bucket, key, 1);
        }
        return;
    }
    let work: Vec<std::sync::Mutex<Option<&mut [T]>>> =
        buckets.into_iter().map(|b| std::sync::Mutex::new(Some(b))).collect();
    let next = std::sync::atomic::AtomicUsize::new(0);
    std::thread::scope(|s| {
        let work = &work;
        for _ in 0..threads.min(work.len()) {
            let next = &next;
            s.spawn(move || loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= work.len() {
                    break;
                }
                if let Some(bucket) = work[i].lock().unwrap().take() {
                    radix_pass(bucket, key, 1);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn random_vec(n: usize, seed: u64, bound: u64) -> Vec<u64> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| r.gen_range(bound)).collect()
    }

    #[test]
    fn seq_sort_matches_std() {
        for (n, bound) in [(0usize, 10u64), (1, 10), (5, 3), (100, 1000), (5000, 50)] {
            let mut a = random_vec(n, 42 + n as u64, bound);
            let mut b = a.clone();
            seq_sort_by_key(&mut a, |x| *x);
            b.sort_unstable();
            assert_eq!(a, b, "n={n} bound={bound}");
        }
    }

    #[test]
    fn seq_sort_already_sorted_and_reversed() {
        let mut asc: Vec<u64> = (0..1000).collect();
        seq_sort_by_key(&mut asc, |x| *x);
        assert!(is_sorted_by_key(&asc, |x| *x));
        let mut desc: Vec<u64> = (0..1000).rev().collect();
        seq_sort_by_key(&mut desc, |x| *x);
        assert!(is_sorted_by_key(&desc, |x| *x));
    }

    #[test]
    fn seq_sort_all_equal() {
        let mut v = vec![7u64; 4096];
        seq_sort_by_key(&mut v, |x| *x);
        assert!(v.iter().all(|&x| x == 7));
    }

    #[test]
    fn par_sort_matches_std_large() {
        for threads in [1usize, 2, 4, 8] {
            for bound in [u64::MAX, 1000, 10, 2] {
                let mut a = random_vec(100_000, 7 + threads as u64, bound);
                let mut b = a.clone();
                par_sort_by_key(&mut a, |x| *x, threads);
                b.sort_unstable();
                assert_eq!(a, b, "threads={threads} bound={bound}");
            }
        }
    }

    #[test]
    fn par_sort_composite_key() {
        // Sort records by (pid, date) like the dbmart pre-mining sort.
        let mut r = Rng::new(99);
        let mut recs: Vec<(u32, u32, u64)> = (0..50_000)
            .map(|i| (r.gen_range(500) as u32, r.gen_range(3650) as u32, i))
            .collect();
        par_sort_by_key(&mut recs, |&(p, d, _)| ((p as u64) << 32) | d as u64, 4);
        assert!(is_sorted_by_key(&recs, |&(p, d, _)| ((p as u64) << 32) | d as u64));
        // every element still present
        assert_eq!(recs.len(), 50_000);
        let mut payloads: Vec<u64> = recs.iter().map(|&(_, _, x)| x).collect();
        payloads.sort_unstable();
        assert_eq!(payloads, (0..50_000).collect::<Vec<_>>());
    }

    #[test]
    fn par_sort_handles_skew() {
        // 90% of keys identical, rest random — exercises the degenerate
        // bucket guard.
        let mut r = Rng::new(5);
        let mut v: Vec<u64> = (0..80_000)
            .map(|_| if r.gen_bool(0.9) { 42 } else { r.gen_range(1_000_000) })
            .collect();
        let mut expect = v.clone();
        par_sort_by_key(&mut v, |x| *x, 4);
        expect.sort_unstable();
        assert_eq!(v, expect);
    }

    #[test]
    fn par_sort_small_input_falls_back() {
        let mut v = random_vec(100, 3, 50);
        let mut expect = v.clone();
        par_sort_by_key(&mut v, |x| *x, 8);
        expect.sort_unstable();
        assert_eq!(v, expect);
    }

    #[test]
    fn radix_matches_std_large() {
        for threads in [1usize, 4] {
            for bound in [u64::MAX, 1_000_000, 1000, 7, 1] {
                let mut a = random_vec(200_000, 11 + threads as u64, bound);
                let mut b = a.clone();
                par_sort_by_radix_key(&mut a, |x| *x as u128, threads);
                b.sort_unstable();
                assert_eq!(a, b, "threads={threads} bound={bound}");
            }
        }
    }

    #[test]
    fn radix_handles_shared_prefixes() {
        // Keys that differ only in the low byte — the prefix-skip path.
        let mut r = Rng::new(3);
        let base: u128 = 0xDEAD_BEEF_0000_0000_0000_0000_0000_0000;
        let mut v: Vec<u128> = (0..100_000).map(|_| base | r.gen_range(256) as u128).collect();
        let mut expect = v.clone();
        par_sort_by_radix_key(&mut v, |x| *x, 4);
        expect.sort_unstable();
        assert_eq!(v, expect);
    }

    #[test]
    fn radix_composite_record_key() {
        let mut r = Rng::new(21);
        let mut recs: Vec<(u64, u32, u32)> = (0..150_000)
            .map(|i| (r.gen_range(5000), r.gen_range(300) as u32, i as u32))
            .collect();
        par_sort_by_radix_key(&mut recs, |&(s, p, _)| ((s as u128) << 32) | p as u128, 4);
        assert!(is_sorted_by_key(&recs, |&(s, p, _)| ((s as u128) << 32) | p as u128));
        assert_eq!(recs.len(), 150_000);
    }

    #[test]
    fn radix_small_and_empty() {
        let mut empty: Vec<u64> = Vec::new();
        par_sort_by_radix_key(&mut empty, |x| *x as u128, 4);
        let mut one = vec![9u64];
        par_sort_by_radix_key(&mut one, |x| *x as u128, 4);
        assert_eq!(one, vec![9]);
        let mut small = random_vec(50, 2, 100);
        let mut expect = small.clone();
        par_sort_by_radix_key(&mut small, |x| *x as u128, 4);
        expect.sort_unstable();
        assert_eq!(small, expect);
    }

    #[test]
    fn property_random_shapes() {
        // Hand-rolled property test: many random (size, bound, threads).
        let mut meta = Rng::new(2024);
        for case in 0..30 {
            let n = meta.gen_range(200_000) as usize;
            let shift = meta.gen_range(40);
            let bound = 1 + meta.gen_range(1 << shift);
            let threads = 1 + meta.gen_range(8) as usize;
            let mut v = random_vec(n, case, bound);
            let mut expect = v.clone();
            par_sort_by_key(&mut v, |x| *x, threads);
            expect.sort_unstable();
            assert_eq!(v, expect, "case={case} n={n} bound={bound} threads={threads}");
        }
    }
}
