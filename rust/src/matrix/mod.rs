//! Patient × sequence matrices — the bridge from mined sequences to the
//! ML layer.
//!
//! Downstream analytics (MSMR mutual information, the MLHO classifier,
//! the Post-COVID correlation step) consume a binary patient×sequence
//! occurrence matrix. Mined records are sparse, so the matrix is built in
//! CSR form and densified per tile when feeding the PJRT artifacts
//! (which take dense `f32` blocks).

use crate::mining::SeqRecord;
use std::collections::HashMap;

/// Binary patient × sequence occurrence matrix (CSR over patients).
#[derive(Clone, Debug, Default)]
pub struct SeqMatrix {
    /// Column order: distinct sequence ids, ascending.
    pub seq_ids: Vec<u64>,
    /// Number of patient rows (dense patient id space).
    pub num_patients: u32,
    /// CSR row pointers (len = num_patients + 1).
    pub row_ptr: Vec<usize>,
    /// Column indices per row, ascending within a row.
    pub col_idx: Vec<u32>,
}

impl SeqMatrix {
    /// Build from mined records. `num_patients` fixes the row space (use
    /// the dbmart's patient count so rows align with labels).
    pub fn build(records: &[SeqRecord], num_patients: u32) -> SeqMatrix {
        // Column dictionary.
        let mut seq_ids: Vec<u64> = records.iter().map(|r| r.seq).collect();
        seq_ids.sort_unstable();
        seq_ids.dedup();
        let col_of: HashMap<u64, u32> =
            seq_ids.iter().enumerate().map(|(i, &s)| (s, i as u32)).collect();

        // Per-row column sets (deduplicated occurrences).
        let mut rows: Vec<Vec<u32>> = vec![Vec::new(); num_patients as usize];
        for r in records {
            debug_assert!(r.pid < num_patients, "record pid outside matrix rows");
            rows[r.pid as usize].push(col_of[&r.seq]);
        }
        let mut row_ptr = Vec::with_capacity(num_patients as usize + 1);
        let mut col_idx = Vec::new();
        row_ptr.push(0);
        for row in &mut rows {
            row.sort_unstable();
            row.dedup();
            col_idx.extend_from_slice(row);
            row_ptr.push(col_idx.len());
        }
        SeqMatrix { seq_ids, num_patients, row_ptr, col_idx }
    }

    /// Number of feature columns.
    pub fn num_cols(&self) -> usize {
        self.seq_ids.len()
    }

    /// Non-zero count.
    pub fn nnz(&self) -> usize {
        self.col_idx.len()
    }

    /// Does (patient, column) hold a 1?
    pub fn get(&self, pid: u32, col: u32) -> bool {
        let r = &self.col_idx[self.row_ptr[pid as usize]..self.row_ptr[pid as usize + 1]];
        r.binary_search(&col).is_ok()
    }

    /// Densify rows `[row0, row0+n_rows)` × cols `[col0, col0+n_cols)`
    /// into a row-major `f32` tile (zero-padded past the matrix edge) —
    /// the feed format of the PJRT artifacts.
    pub fn dense_tile(&self, row0: u32, n_rows: usize, col0: u32, n_cols: usize) -> Vec<f32> {
        let mut out = vec![0f32; n_rows * n_cols];
        for i in 0..n_rows {
            let pid = row0 as usize + i;
            if pid >= self.num_patients as usize {
                break;
            }
            let cols = &self.col_idx[self.row_ptr[pid]..self.row_ptr[pid + 1]];
            let start = cols.partition_point(|&c| (c as usize) < col0 as usize);
            for &c in &cols[start..] {
                let off = c as usize - col0 as usize;
                if off >= n_cols {
                    break;
                }
                out[i * n_cols + off] = 1.0;
            }
        }
        out
    }

    /// Full dense matrix (use only for small shapes / tests).
    pub fn to_dense(&self) -> Vec<f32> {
        self.dense_tile(0, self.num_patients as usize, 0, self.num_cols())
    }

    /// Column-wise positive counts (patients per sequence).
    pub fn col_counts(&self) -> Vec<u32> {
        let mut counts = vec![0u32; self.num_cols()];
        for &c in &self.col_idx {
            counts[c as usize] += 1;
        }
        counts
    }

    /// Build a **duration-aware** matrix: each column is a
    /// `(sequence, duration-bucket)` pair, encoded with the paper's
    /// bit-shift packing ([`crate::dbmart::pack_duration`]). This is the
    /// "new dimension" tSPM+ adds over tSPM — the same sequence occurring
    /// promptly vs. months later becomes a *different* feature, which is
    /// what the Post-COVID use case and the duration-sparsity screen
    /// exploit.
    pub fn build_with_durations(
        records: &[SeqRecord],
        num_patients: u32,
        bucket_days: u32,
    ) -> SeqMatrix {
        let bucket = bucket_days.max(1);
        let packed: Vec<SeqRecord> = records
            .iter()
            .map(|r| SeqRecord {
                seq: crate::dbmart::pack_duration(r.seq, r.duration / bucket),
                pid: r.pid,
                duration: r.duration,
            })
            .collect();
        SeqMatrix::build(&packed, num_patients)
    }

    /// Decode a duration-aware column back to `(sequence, bucket)`.
    /// Only meaningful for matrices from [`SeqMatrix::build_with_durations`].
    pub fn column_seq_bucket(&self, col: u32) -> (u64, u32) {
        crate::dbmart::unpack_duration(self.seq_ids[col as usize])
    }

    /// Select a column subset, producing a new matrix whose columns are
    /// `cols` (in the given order).
    pub fn select_columns(&self, cols: &[u32]) -> SeqMatrix {
        let remap: HashMap<u32, u32> =
            cols.iter().enumerate().map(|(i, &c)| (c, i as u32)).collect();
        let mut row_ptr = Vec::with_capacity(self.num_patients as usize + 1);
        let mut col_idx = Vec::new();
        row_ptr.push(0);
        for pid in 0..self.num_patients as usize {
            let r = &self.col_idx[self.row_ptr[pid]..self.row_ptr[pid + 1]];
            let mut new_cols: Vec<u32> =
                r.iter().filter_map(|c| remap.get(c).copied()).collect();
            new_cols.sort_unstable();
            col_idx.extend_from_slice(&new_cols);
            row_ptr.push(col_idx.len());
        }
        SeqMatrix {
            seq_ids: cols.iter().map(|&c| self.seq_ids[c as usize]).collect(),
            num_patients: self.num_patients,
            row_ptr,
            col_idx,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dbmart::encode_seq;

    fn rec(seq: u64, pid: u32) -> SeqRecord {
        SeqRecord { seq, pid, duration: 0 }
    }

    #[test]
    fn build_dedupes_and_orders() {
        let records = vec![
            rec(encode_seq(2, 1), 0),
            rec(encode_seq(1, 1), 0),
            rec(encode_seq(1, 1), 0), // duplicate occurrence
            rec(encode_seq(1, 1), 2),
        ];
        let m = SeqMatrix::build(&records, 3);
        assert_eq!(m.num_cols(), 2);
        assert_eq!(m.seq_ids, vec![encode_seq(1, 1), encode_seq(2, 1)]);
        assert_eq!(m.nnz(), 3);
        assert!(m.get(0, 0) && m.get(0, 1));
        assert!(!m.get(1, 0));
        assert!(m.get(2, 0) && !m.get(2, 1));
    }

    #[test]
    fn dense_tile_matches_get() {
        let records = vec![
            rec(10, 0),
            rec(20, 0),
            rec(30, 1),
            rec(10, 3),
        ];
        let m = SeqMatrix::build(&records, 4);
        let dense = m.to_dense();
        for pid in 0..4u32 {
            for col in 0..3u32 {
                let expect = if m.get(pid, col) { 1.0 } else { 0.0 };
                assert_eq!(dense[(pid as usize) * 3 + col as usize], expect);
            }
        }
    }

    #[test]
    fn dense_tile_pads_beyond_edges() {
        let m = SeqMatrix::build(&[rec(10, 0)], 1);
        let tile = m.dense_tile(0, 4, 0, 8);
        assert_eq!(tile.len(), 32);
        assert_eq!(tile[0], 1.0);
        assert_eq!(tile.iter().filter(|&&v| v != 0.0).count(), 1);
    }

    #[test]
    fn dense_tile_offsets() {
        let records = vec![rec(10, 0), rec(20, 0), rec(30, 0), rec(20, 1)];
        let m = SeqMatrix::build(&records, 2);
        // tile over cols [1,3) = seqs 20,30
        let tile = m.dense_tile(0, 2, 1, 2);
        assert_eq!(tile, vec![1.0, 1.0, 1.0, 0.0]);
    }

    #[test]
    fn col_counts_are_patientwise() {
        let records = vec![rec(10, 0), rec(10, 0), rec(10, 1), rec(20, 1)];
        let m = SeqMatrix::build(&records, 2);
        assert_eq!(m.col_counts(), vec![2, 1]);
    }

    #[test]
    fn select_columns_projects() {
        let records = vec![rec(10, 0), rec(20, 0), rec(30, 1)];
        let m = SeqMatrix::build(&records, 2);
        let sel = m.select_columns(&[2, 0]); // seqs 30, 10
        assert_eq!(sel.seq_ids, vec![30, 10]);
        assert!(sel.get(1, 0)); // seq 30 for patient 1 → new col 0
        assert!(sel.get(0, 1)); // seq 10 for patient 0 → new col 1
        assert!(!sel.get(0, 0));
        assert_eq!(sel.nnz(), 2);
    }

    #[test]
    fn duration_buckets_split_columns() {
        // same sequence, three duration regimes → distinct columns
        let records = vec![
            SeqRecord { seq: 10, pid: 0, duration: 5 },
            SeqRecord { seq: 10, pid: 1, duration: 35 },
            SeqRecord { seq: 10, pid: 2, duration: 95 },
            SeqRecord { seq: 10, pid: 3, duration: 36 }, // same bucket as pid 1
        ];
        let m = SeqMatrix::build_with_durations(&records, 4, 30);
        assert_eq!(m.num_cols(), 3);
        let buckets: Vec<u32> =
            (0..m.num_cols() as u32).map(|c| m.column_seq_bucket(c).1).collect();
        assert_eq!(buckets, vec![0, 1, 3]);
        assert!(m.get(1, 1) && m.get(3, 1), "bucket-1 column shared by pids 1 and 3");
        // every column decodes back to the original sequence id
        for c in 0..m.num_cols() as u32 {
            assert_eq!(m.column_seq_bucket(c).0, 10);
        }
    }

    #[test]
    fn duration_matrix_without_buckets_matches_plain_when_durations_equal() {
        let records = vec![rec(10, 0), rec(20, 1)]; // all durations 0
        let plain = SeqMatrix::build(&records, 2);
        let dur = SeqMatrix::build_with_durations(&records, 2, 30);
        assert_eq!(plain.num_cols(), dur.num_cols());
        assert_eq!(plain.nnz(), dur.nnz());
    }

    #[test]
    fn empty_matrix() {
        let m = SeqMatrix::build(&[], 5);
        assert_eq!(m.num_cols(), 0);
        assert_eq!(m.nnz(), 0);
        assert_eq!(m.to_dense().len(), 0);
    }
}
