//! Patient × sequence matrices — the bridge from mined sequences to the
//! ML layer.
//!
//! Downstream analytics (MSMR mutual information, the MLHO classifier,
//! the Post-COVID correlation step) consume a binary patient×sequence
//! occurrence matrix. Mined records are sparse, so the matrix is built in
//! CSR form and densified per tile when feeding the PJRT artifacts
//! (which take dense `f32` blocks).
//!
//! Two builders produce bit-identical CSR output:
//!
//! * [`SeqMatrix::build`] over in-memory records (the classic path);
//! * [`SeqMatrix::from_index`] streams a [`crate::query::SeqIndex`]
//!   artifact block-at-a-time — the out-of-core path: the record
//!   multiset is never materialized, the resident set is one read block
//!   plus the output CSR itself (MemTracker-proven in the conformance
//!   tests).

use crate::metrics::MemTracker;
use crate::mining::SeqRecord;
use crate::query::SeqIndex;
use crate::seqstore::{SeqReader, RECORD_BYTES};
use std::collections::HashMap;
use std::fmt;

/// Errors of the matrix builders.
#[derive(Debug)]
pub enum MatrixError {
    /// A record's pid falls outside the declared row space — previously
    /// a `debug_assert!` only, which in release builds surfaced as an
    /// uncontextual index-out-of-bounds panic.
    PidOutOfRange {
        pid: u32,
        num_patients: u32,
    },
    /// IO failures while streaming an index artifact.
    Io(std::io::Error),
    /// The index artifact and its data file disagree (corrupt or
    /// hand-edited artifact).
    Artifact(String),
}

impl fmt::Display for MatrixError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            MatrixError::PidOutOfRange { pid, num_patients } => write!(
                f,
                "matrix: record pid {pid} is outside the {num_patients}-row patient \
                 space — build the matrix with the cohort's patient count"
            ),
            MatrixError::Io(e) => write!(f, "matrix io error: {e}"),
            MatrixError::Artifact(msg) => write!(f, "matrix artifact error: {msg}"),
        }
    }
}

impl std::error::Error for MatrixError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            MatrixError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for MatrixError {
    fn from(e: std::io::Error) -> Self {
        MatrixError::Io(e)
    }
}

/// Binary patient × sequence occurrence matrix (CSR over patients).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct SeqMatrix {
    /// Column order: distinct sequence ids, ascending.
    pub seq_ids: Vec<u64>,
    /// Number of patient rows (dense patient id space).
    pub num_patients: u32,
    /// CSR row pointers (len = num_patients + 1).
    pub row_ptr: Vec<usize>,
    /// Column indices per row, ascending within a row.
    pub col_idx: Vec<u32>,
}

impl SeqMatrix {
    /// Build from mined records. `num_patients` fixes the row space (use
    /// the dbmart's patient count so rows align with labels). A record
    /// whose pid falls outside that space is a typed
    /// [`MatrixError::PidOutOfRange`], not a release-mode panic.
    pub fn build(records: &[SeqRecord], num_patients: u32) -> Result<SeqMatrix, MatrixError> {
        // Validate the row space up front so the fill loop below can
        // index unchecked-by-construction.
        if let Some(r) = records.iter().find(|r| r.pid >= num_patients) {
            return Err(MatrixError::PidOutOfRange { pid: r.pid, num_patients });
        }
        // Column dictionary.
        let mut seq_ids: Vec<u64> = records.iter().map(|r| r.seq).collect();
        seq_ids.sort_unstable();
        seq_ids.dedup();
        let col_of: HashMap<u64, u32> =
            seq_ids.iter().enumerate().map(|(i, &s)| (s, i as u32)).collect();

        // Per-row column sets (deduplicated occurrences).
        let mut rows: Vec<Vec<u32>> = vec![Vec::new(); num_patients as usize];
        for r in records {
            rows[r.pid as usize].push(col_of[&r.seq]);
        }
        let mut row_ptr = Vec::with_capacity(num_patients as usize + 1);
        let mut col_idx = Vec::new();
        row_ptr.push(0);
        for row in &mut rows {
            row.sort_unstable();
            row.dedup();
            col_idx.extend_from_slice(row);
            row_ptr.push(col_idx.len());
        }
        Ok(SeqMatrix { seq_ids, num_patients, row_ptr, col_idx })
    }

    /// Build the CSR matrix **straight from an index artifact**, without
    /// ever materializing the record multiset: the artifact's
    /// sequence-major data file is exactly the CSC orientation of this
    /// matrix, so two block-at-a-time streaming passes (count rows, then
    /// fill) transpose it into CSR. Output is bit-identical to
    /// [`SeqMatrix::build`] on the materialized records — all four
    /// fields — and the resident set is one read block plus the output
    /// CSR arrays.
    pub fn from_index(idx: &SeqIndex, num_patients: u32) -> Result<SeqMatrix, MatrixError> {
        SeqMatrix::from_index_tracked(idx, num_patients, None, None)
    }

    /// [`SeqMatrix::from_index`] in the duration-aware column space —
    /// bit-identical to [`SeqMatrix::build_with_durations`].
    pub fn from_index_with_durations(
        idx: &SeqIndex,
        num_patients: u32,
        bucket_days: u32,
    ) -> Result<SeqMatrix, MatrixError> {
        SeqMatrix::from_index_tracked(idx, num_patients, Some(bucket_days), None)
    }

    /// The full-control index-fed builder: `bucket_days` switches to the
    /// duration-aware column space, `tracker` accounts every buffer and
    /// the output arrays so tests can prove the O(block + CSR) bound.
    pub fn from_index_tracked(
        idx: &SeqIndex,
        num_patients: u32,
        bucket_days: Option<u32>,
        tracker: Option<&MemTracker>,
    ) -> Result<SeqMatrix, MatrixError> {
        let track = |b: u64| {
            if let Some(t) = tracker {
                t.add(b)
            }
        };
        let untrack = |b: u64| {
            if let Some(t) = tracker {
                t.sub(b)
            }
        };
        let bucket = bucket_days.map(|b| b.max(1));
        let pack = |r: SeqRecord| match bucket {
            Some(b) => crate::dbmart::pack_duration(r.seq, r.duration / b),
            None => r.seq,
        };

        // One read block is the streaming unit of both passes.
        let cap = idx.block_records.clamp(1, 64 * 1024);
        let buf_bytes = (cap * RECORD_BYTES) as u64;

        // Pass 1: count each row's distinct columns and collect the
        // column dictionary. The data is (seq, pid, duration)-sorted, so
        // duplicate (column, pid) entries are always consecutive — one
        // previous-record comparison is a full dedup.
        let n_rows = num_patients as usize;
        let mut row_counts = vec![0u32; n_rows];
        track(n_rows as u64 * 4);
        // Plain columns come free from the resident per-seq table; the
        // duration-aware space needs collecting (consecutive-duplicate
        // pushes, then sort+dedup — bounded by the matrix nnz).
        let mut packed_cols: Vec<u64> = Vec::new();
        let mut seen_seqs = 0usize;
        {
            let mut prev: Option<(u64, u32, u64)> = None; // (seq, pid, packed)
            track(buf_bytes);
            let pass = stream_index_records(idx, cap, |r, _| {
                if r.pid >= num_patients {
                    return Err(MatrixError::PidOutOfRange { pid: r.pid, num_patients });
                }
                let packed = pack(r);
                if prev.map_or(true, |(s, _, _)| s != r.seq) {
                    seen_seqs += 1;
                }
                if prev.map_or(true, |(_, p, k)| p != r.pid || k != packed) {
                    row_counts[r.pid as usize] += 1;
                    if bucket.is_some() && packed_cols.last() != Some(&packed) {
                        packed_cols.push(packed);
                    }
                }
                prev = Some((r.seq, r.pid, packed));
                Ok(())
            });
            untrack(buf_bytes);
            pass?;
        }
        if seen_seqs != idx.seqs.len() {
            return Err(MatrixError::Artifact(format!(
                "{}: data file holds {seen_seqs} distinct sequences but the sequence \
                 table lists {}",
                idx.data_path.display(),
                idx.seqs.len()
            )));
        }
        let packed_temp_bytes = packed_cols.len() as u64 * 8;
        track(packed_temp_bytes);
        let seq_ids: Vec<u64> = match bucket {
            Some(_) => {
                let mut cols = std::mem::take(&mut packed_cols);
                cols.sort_unstable();
                cols.dedup();
                cols.shrink_to_fit();
                cols
            }
            None => idx.seqs.iter().map(|e| e.seq).collect(),
        };
        untrack(packed_temp_bytes);
        if seq_ids.len() > u32::MAX as usize {
            return Err(MatrixError::Artifact(format!(
                "{} distinct columns overflow the u32 column index space",
                seq_ids.len()
            )));
        }
        let seq_ids_bytes = seq_ids.len() as u64 * 8;
        track(seq_ids_bytes);

        // Row pointers from the counts; per-row write cursors.
        let mut row_ptr = Vec::with_capacity(n_rows + 1);
        row_ptr.push(0usize);
        for &c in &row_counts {
            row_ptr.push(row_ptr.last().unwrap() + c as usize);
        }
        let nnz = *row_ptr.last().unwrap();
        let mut cursors: Vec<usize> = row_ptr[..n_rows].to_vec();
        let ptr_bytes = (row_ptr.len() as u64 + cursors.len() as u64) * 8;
        track(ptr_bytes);
        let mut col_idx = vec![0u32; nnz];
        track(nnz as u64 * 4);

        // Pass 2: fill. Within one row the stream visits columns in
        // ascending order (sequences ascend globally; inside one
        // (seq, pid) run durations — hence buckets — ascend), so the
        // rows come out sorted without a sort.
        {
            let mut prev: Option<(u64, u32, u64)> = None;
            let mut cur_col = 0usize; // plain path: walks idx.seqs in lockstep
            track(buf_bytes);
            let pass = stream_index_records(idx, cap, |r, _| {
                // Re-validate: the file is re-read, so a swap between
                // the passes must stay a typed error, not an
                // out-of-bounds panic on `cursors[r.pid]`.
                if r.pid >= num_patients {
                    return Err(MatrixError::PidOutOfRange { pid: r.pid, num_patients });
                }
                let packed = pack(r);
                let col = match bucket {
                    Some(_) => seq_ids
                        .binary_search(&packed)
                        .map_err(|_| {
                            MatrixError::Artifact(format!(
                                "{}: column {packed} missing from the dictionary — \
                                 the data file changed between passes",
                                idx.data_path.display()
                            ))
                        })? as u32,
                    None => {
                        if prev.map_or(true, |(s, _, _)| s != r.seq) {
                            if prev.is_some() {
                                cur_col += 1;
                            }
                            if seq_ids.get(cur_col) != Some(&r.seq) {
                                return Err(MatrixError::Artifact(format!(
                                    "{}: sequence {} in the data file disagrees with \
                                     the sequence table — the artifact is corrupt \
                                     (or changed between passes)",
                                    idx.data_path.display(),
                                    r.seq
                                )));
                            }
                        }
                        cur_col as u32
                    }
                };
                if prev.map_or(true, |(_, p, k)| p != r.pid || k != packed) {
                    let cursor = &mut cursors[r.pid as usize];
                    col_idx[*cursor] = col;
                    *cursor += 1;
                }
                prev = Some((r.seq, r.pid, packed));
                Ok(())
            });
            untrack(buf_bytes);
            pass?;
        }
        debug_assert!(cursors.iter().zip(&row_ptr[1..]).all(|(c, e)| c == e));

        // Release everything we accounted: the temporaries die here, the
        // CSR arrays transfer to the caller (who re-accounts them if it
        // keeps its own books — the engine does). The tracker peak over
        // this call is the O(block + output CSR) proof.
        drop(cursors);
        drop(row_counts);
        untrack(ptr_bytes);
        untrack(n_rows as u64 * 4);
        untrack(seq_ids_bytes);
        untrack(nnz as u64 * 4);

        Ok(SeqMatrix { seq_ids, num_patients, row_ptr, col_idx })
    }

    /// Number of feature columns.
    pub fn num_cols(&self) -> usize {
        self.seq_ids.len()
    }

    /// Non-zero count.
    pub fn nnz(&self) -> usize {
        self.col_idx.len()
    }

    /// Does (patient, column) hold a 1?
    pub fn get(&self, pid: u32, col: u32) -> bool {
        let r = &self.col_idx[self.row_ptr[pid as usize]..self.row_ptr[pid as usize + 1]];
        r.binary_search(&col).is_ok()
    }

    /// Densify rows `[row0, row0+n_rows)` × cols `[col0, col0+n_cols)`
    /// into a row-major `f32` tile (zero-padded past the matrix edge) —
    /// the feed format of the PJRT artifacts.
    pub fn dense_tile(&self, row0: u32, n_rows: usize, col0: u32, n_cols: usize) -> Vec<f32> {
        let mut out = vec![0f32; n_rows * n_cols];
        for i in 0..n_rows {
            let pid = row0 as usize + i;
            if pid >= self.num_patients as usize {
                break;
            }
            let cols = &self.col_idx[self.row_ptr[pid]..self.row_ptr[pid + 1]];
            let start = cols.partition_point(|&c| (c as usize) < col0 as usize);
            for &c in &cols[start..] {
                let off = c as usize - col0 as usize;
                if off >= n_cols {
                    break;
                }
                out[i * n_cols + off] = 1.0;
            }
        }
        out
    }

    /// Full dense matrix (use only for small shapes / tests).
    pub fn to_dense(&self) -> Vec<f32> {
        self.dense_tile(0, self.num_patients as usize, 0, self.num_cols())
    }

    /// Column-wise positive counts (patients per sequence).
    pub fn col_counts(&self) -> Vec<u32> {
        let mut counts = vec![0u32; self.num_cols()];
        for &c in &self.col_idx {
            counts[c as usize] += 1;
        }
        counts
    }

    /// Build a **duration-aware** matrix: each column is a
    /// `(sequence, duration-bucket)` pair, encoded with the paper's
    /// bit-shift packing ([`crate::dbmart::pack_duration`]). This is the
    /// "new dimension" tSPM+ adds over tSPM — the same sequence occurring
    /// promptly vs. months later becomes a *different* feature, which is
    /// what the Post-COVID use case and the duration-sparsity screen
    /// exploit.
    pub fn build_with_durations(
        records: &[SeqRecord],
        num_patients: u32,
        bucket_days: u32,
    ) -> Result<SeqMatrix, MatrixError> {
        let bucket = bucket_days.max(1);
        let packed: Vec<SeqRecord> = records
            .iter()
            .map(|r| SeqRecord {
                seq: crate::dbmart::pack_duration(r.seq, r.duration / bucket),
                pid: r.pid,
                duration: r.duration,
            })
            .collect();
        SeqMatrix::build(&packed, num_patients)
    }

    /// Decode a duration-aware column back to `(sequence, bucket)`.
    /// Only meaningful for matrices from [`SeqMatrix::build_with_durations`].
    pub fn column_seq_bucket(&self, col: u32) -> (u64, u32) {
        crate::dbmart::unpack_duration(self.seq_ids[col as usize])
    }

    /// Select a column subset, producing a new matrix whose columns are
    /// `cols` (in the given order).
    pub fn select_columns(&self, cols: &[u32]) -> SeqMatrix {
        let remap: HashMap<u32, u32> =
            cols.iter().enumerate().map(|(i, &c)| (c, i as u32)).collect();
        let mut row_ptr = Vec::with_capacity(self.num_patients as usize + 1);
        let mut col_idx = Vec::new();
        row_ptr.push(0);
        for pid in 0..self.num_patients as usize {
            let r = &self.col_idx[self.row_ptr[pid]..self.row_ptr[pid + 1]];
            let mut new_cols: Vec<u32> =
                r.iter().filter_map(|c| remap.get(c).copied()).collect();
            new_cols.sort_unstable();
            col_idx.extend_from_slice(&new_cols);
            row_ptr.push(col_idx.len());
        }
        SeqMatrix {
            seq_ids: cols.iter().map(|&c| self.seq_ids[c as usize]).collect(),
            num_patients: self.num_patients,
            row_ptr,
            col_idx,
        }
    }
}

/// Stream every record of the artifact's sequence-major data file in
/// order, block at a time (`cap` records per read), through `f` — which
/// also receives the record's 0-based position. The total is
/// cross-checked against the manifest so a file swapped mid-build fails
/// loudly.
fn stream_index_records(
    idx: &SeqIndex,
    cap: usize,
    mut f: impl FnMut(SeqRecord, u64) -> Result<(), MatrixError>,
) -> Result<(), MatrixError> {
    let mut reader = SeqReader::open_with_capacity(&idx.data_path, cap * RECORD_BYTES)?;
    let mut buf = vec![SeqRecord { seq: 0, pid: 0, duration: 0 }; cap];
    let mut pos = 0u64;
    loop {
        let got = reader.read_batch(&mut buf)?;
        if got == 0 {
            break;
        }
        for &r in &buf[..got] {
            f(r, pos)?;
            pos += 1;
        }
    }
    if pos != idx.total_records {
        return Err(MatrixError::Artifact(format!(
            "{}: data file holds {pos} records but the manifest claims {}",
            idx.data_path.display(),
            idx.total_records
        )));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dbmart::encode_seq;

    fn rec(seq: u64, pid: u32) -> SeqRecord {
        SeqRecord { seq, pid, duration: 0 }
    }

    #[test]
    fn build_dedupes_and_orders() {
        let records = vec![
            rec(encode_seq(2, 1), 0),
            rec(encode_seq(1, 1), 0),
            rec(encode_seq(1, 1), 0), // duplicate occurrence
            rec(encode_seq(1, 1), 2),
        ];
        let m = SeqMatrix::build(&records, 3).unwrap();
        assert_eq!(m.num_cols(), 2);
        assert_eq!(m.seq_ids, vec![encode_seq(1, 1), encode_seq(2, 1)]);
        assert_eq!(m.nnz(), 3);
        assert!(m.get(0, 0) && m.get(0, 1));
        assert!(!m.get(1, 0));
        assert!(m.get(2, 0) && !m.get(2, 1));
    }

    #[test]
    fn dense_tile_matches_get() {
        let records = vec![
            rec(10, 0),
            rec(20, 0),
            rec(30, 1),
            rec(10, 3),
        ];
        let m = SeqMatrix::build(&records, 4).unwrap();
        let dense = m.to_dense();
        for pid in 0..4u32 {
            for col in 0..3u32 {
                let expect = if m.get(pid, col) { 1.0 } else { 0.0 };
                assert_eq!(dense[(pid as usize) * 3 + col as usize], expect);
            }
        }
    }

    #[test]
    fn dense_tile_pads_beyond_edges() {
        let m = SeqMatrix::build(&[rec(10, 0)], 1).unwrap();
        let tile = m.dense_tile(0, 4, 0, 8);
        assert_eq!(tile.len(), 32);
        assert_eq!(tile[0], 1.0);
        assert_eq!(tile.iter().filter(|&&v| v != 0.0).count(), 1);
    }

    #[test]
    fn dense_tile_offsets() {
        let records = vec![rec(10, 0), rec(20, 0), rec(30, 0), rec(20, 1)];
        let m = SeqMatrix::build(&records, 2).unwrap();
        // tile over cols [1,3) = seqs 20,30
        let tile = m.dense_tile(0, 2, 1, 2);
        assert_eq!(tile, vec![1.0, 1.0, 1.0, 0.0]);
    }

    #[test]
    fn col_counts_are_patientwise() {
        let records = vec![rec(10, 0), rec(10, 0), rec(10, 1), rec(20, 1)];
        let m = SeqMatrix::build(&records, 2).unwrap();
        assert_eq!(m.col_counts(), vec![2, 1]);
    }

    #[test]
    fn select_columns_projects() {
        let records = vec![rec(10, 0), rec(20, 0), rec(30, 1)];
        let m = SeqMatrix::build(&records, 2).unwrap();
        let sel = m.select_columns(&[2, 0]); // seqs 30, 10
        assert_eq!(sel.seq_ids, vec![30, 10]);
        assert!(sel.get(1, 0)); // seq 30 for patient 1 → new col 0
        assert!(sel.get(0, 1)); // seq 10 for patient 0 → new col 1
        assert!(!sel.get(0, 0));
        assert_eq!(sel.nnz(), 2);
    }

    #[test]
    fn duration_buckets_split_columns() {
        // same sequence, three duration regimes → distinct columns
        let records = vec![
            SeqRecord { seq: 10, pid: 0, duration: 5 },
            SeqRecord { seq: 10, pid: 1, duration: 35 },
            SeqRecord { seq: 10, pid: 2, duration: 95 },
            SeqRecord { seq: 10, pid: 3, duration: 36 }, // same bucket as pid 1
        ];
        let m = SeqMatrix::build_with_durations(&records, 4, 30).unwrap();
        assert_eq!(m.num_cols(), 3);
        let buckets: Vec<u32> =
            (0..m.num_cols() as u32).map(|c| m.column_seq_bucket(c).1).collect();
        assert_eq!(buckets, vec![0, 1, 3]);
        assert!(m.get(1, 1) && m.get(3, 1), "bucket-1 column shared by pids 1 and 3");
        // every column decodes back to the original sequence id
        for c in 0..m.num_cols() as u32 {
            assert_eq!(m.column_seq_bucket(c).0, 10);
        }
    }

    #[test]
    fn duration_matrix_without_buckets_matches_plain_when_durations_equal() {
        let records = vec![rec(10, 0), rec(20, 1)]; // all durations 0
        let plain = SeqMatrix::build(&records, 2).unwrap();
        let dur = SeqMatrix::build_with_durations(&records, 2, 30).unwrap();
        assert_eq!(plain.num_cols(), dur.num_cols());
        assert_eq!(plain.nnz(), dur.nnz());
    }

    #[test]
    fn empty_matrix() {
        let m = SeqMatrix::build(&[], 5).unwrap();
        assert_eq!(m.num_cols(), 0);
        assert_eq!(m.nnz(), 0);
        assert_eq!(m.to_dense().len(), 0);
    }

    #[test]
    fn pid_outside_the_row_space_is_a_typed_error_not_a_panic() {
        // Regression: this was a debug_assert!, so release builds hit an
        // uncontextual index-out-of-bounds panic on rows[r.pid].
        let records = vec![rec(10, 0), rec(20, 5)];
        let err = SeqMatrix::build(&records, 3).unwrap_err();
        match err {
            MatrixError::PidOutOfRange { pid, num_patients } => {
                assert_eq!((pid, num_patients), (5, 3));
            }
            other => panic!("expected PidOutOfRange, got {other}"),
        }
        assert!(err.to_string().contains("pid 5"), "got {err}");
        // The duration-aware builder shares the validation.
        let err = SeqMatrix::build_with_durations(&records, 3, 30).unwrap_err();
        assert!(matches!(err, MatrixError::PidOutOfRange { .. }));
        // The boundary pid is fine.
        SeqMatrix::build(&records, 6).unwrap();
    }

    #[test]
    fn from_index_round_trips_small_artifacts() {
        use crate::query::{index, IndexConfig};
        use crate::seqstore::SeqFileSet;
        let dir = std::env::temp_dir()
            .join(format!("tspm_matrix_from_index_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let mut records = vec![
            SeqRecord { seq: 10, pid: 0, duration: 5 },
            SeqRecord { seq: 10, pid: 0, duration: 40 },
            SeqRecord { seq: 10, pid: 2, duration: 35 },
            SeqRecord { seq: 20, pid: 0, duration: 0 },
            SeqRecord { seq: 30, pid: 1, duration: 95 },
            SeqRecord { seq: 30, pid: 1, duration: 95 },
        ];
        records.sort_unstable_by_key(|r| (r.seq, r.pid, r.duration));
        let path = dir.join("in.tspm");
        crate::seqstore::write_file(&path, &records).unwrap();
        let input = SeqFileSet {
            files: vec![path],
            total_records: records.len() as u64,
            num_patients: 4,
            num_phenx: 3,
        };
        let idx = index::build(
            &input,
            &dir.join("idx"),
            &IndexConfig { block_records: 2, ..Default::default() },
            None,
        )
        .unwrap();

        let tracker = MemTracker::new();
        let direct = SeqMatrix::build(&records, 4).unwrap();
        let streamed =
            SeqMatrix::from_index_tracked(&idx, 4, None, Some(&tracker)).unwrap();
        assert_eq!(streamed, direct, "all four CSR fields must match");
        assert_eq!(tracker.live(), 0, "every tracked byte released");
        assert!(tracker.peak() > 0);

        let direct_dur = SeqMatrix::build_with_durations(&records, 4, 30).unwrap();
        let streamed_dur = SeqMatrix::from_index_with_durations(&idx, 4, 30).unwrap();
        assert_eq!(streamed_dur, direct_dur);
        assert!(streamed_dur.num_cols() > direct.num_cols(), "buckets split columns");

        // A row space too small for the artifact's pids is typed.
        let err = SeqMatrix::from_index(&idx, 2).unwrap_err();
        assert!(matches!(err, MatrixError::PidOutOfRange { pid: 2, num_patients: 2 }));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
