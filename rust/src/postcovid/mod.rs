//! Post COVID-19 identification per the WHO definition (vignette 2).
//!
//! WHO (2021): a Post COVID-19 symptom occurs **after** a COVID-19
//! infection, is **ongoing for at least 2 months**, and **cannot be
//! explained by an alternative diagnosis**. The paper's second vignette
//! implements this on transitive sequences + durations; this module is
//! that vignette as a library:
//!
//! 1. **Candidates** — for every patient, sequences `covid → s` give each
//!    symptom's post-infection occurrence offsets (the durations). A
//!    `(patient, s)` pair is a candidate when it recurs
//!    (≥ `min_occurrences`) and persists (duration span ≥
//!    `min_duration_span`, default 60 days).
//! 2. **Pre-existing exclusion** — a sequence `s → covid` proves the
//!    symptom predates the infection; the candidate is excluded
//!    ("excluded by another rationale").
//! 3. **Alternative-diagnosis exclusion** — for each candidate symptom
//!    `s`, every other start `x` with persistent `x → s` patterns is
//!    correlated, across the cohort, against `covid → s` candidacy
//!    (duration-bucket profiles; the `corr_masked` PJRT artifact or the
//!    Rust fallback). When the correlation is high and the patient
//!    carries the persistent `x → s` pattern, `x` explains `s` for that
//!    patient and the candidate is removed.
//!
//! The synthetic COVID scenario ([`crate::synthea`]) plants ground truth
//! plus all three confounder families, so this implementation is
//! *validated*, not just demonstrated (see `examples/postcovid.rs`).

use crate::dbmart::decode_seq;
use crate::mining::SeqRecord;
use crate::runtime::{ArtifactSet, RuntimeError, Tensor};
use crate::util;
use std::collections::{BTreeMap, BTreeSet};

/// Configuration of the WHO-definition implementation.
#[derive(Clone, Debug)]
pub struct PostCovidConfig {
    /// Numeric phenX id of the COVID-19 infection code.
    pub covid_phenx: u32,
    /// Minimum occurrences of `covid → s` per patient (recurrence).
    pub min_occurrences: u32,
    /// Minimum span between first and last occurrence, in duration
    /// units (WHO: 2 months ≈ 60 days).
    pub min_duration_span: u32,
    /// Duration bucket width for the correlation profiles.
    pub bucket_days: u32,
    /// Cohort correlation above which a start phenX `x` counts as an
    /// alternative explanation.
    pub corr_threshold: f32,
    /// Minimum patients carrying persistent `x → s` before `x` is even
    /// considered as an explanation (noise guard).
    pub min_support: u32,
    /// An explanation `x` must *onset* the symptom: the smallest
    /// `x → s` duration must be ≤ this window (days), i.e. the symptom
    /// started shortly after `x` appeared.
    pub onset_window: u32,
    /// Specificity gate: the fraction of all `x`-carrying patients that
    /// exhibit the onsetting persistent `x → s` pattern must reach this
    /// value. Ubiquitous background codes are carried by everyone and
    /// explain almost nobody, so they fail this gate.
    pub strength_min: f32,
    /// Optional restriction of candidate end phenX (e.g. the WHO symptom
    /// list); `None` admits every code.
    pub candidate_filter: Option<BTreeSet<u32>>,
}

impl PostCovidConfig {
    pub fn new(covid_phenx: u32) -> Self {
        PostCovidConfig {
            covid_phenx,
            min_occurrences: 2,
            min_duration_span: 60,
            bucket_days: 30,
            corr_threshold: 0.4,
            min_support: 3,
            onset_window: 45,
            strength_min: 0.5,
            candidate_filter: None,
        }
    }
}

/// Result of the identification.
#[derive(Clone, Debug, Default)]
pub struct PostCovidResult {
    /// Candidates after step 1 (recurrence + persistence).
    pub candidates: BTreeSet<(u32, u32)>,
    /// Final Post-COVID `(patient, symptom)` pairs.
    pub confirmed: BTreeSet<(u32, u32)>,
    /// `(patient, symptom, explaining_start)` removals from step 2/3
    /// (`explaining_start == symptom` encodes the pre-existing rule).
    pub excluded: Vec<(u32, u32, u32)>,
}

/// Validation metrics against generator ground truth.
#[derive(Clone, Copy, Debug, Default)]
pub struct Validation {
    pub true_positives: usize,
    pub false_positives: usize,
    pub false_negatives: usize,
}

impl Validation {
    pub fn precision(&self) -> f64 {
        let denom = self.true_positives + self.false_positives;
        if denom == 0 {
            1.0
        } else {
            self.true_positives as f64 / denom as f64
        }
    }

    pub fn recall(&self) -> f64 {
        let denom = self.true_positives + self.false_negatives;
        if denom == 0 {
            1.0
        } else {
            self.true_positives as f64 / denom as f64
        }
    }

    pub fn f1(&self) -> f64 {
        let p = self.precision();
        let r = self.recall();
        if p + r == 0.0 {
            0.0
        } else {
            2.0 * p * r / (p + r)
        }
    }
}

/// Run the full WHO-definition identification over mined sequences.
pub fn identify(
    records: &[SeqRecord],
    num_patients: u32,
    cfg: &PostCovidConfig,
    artifacts: Option<&ArtifactSet>,
) -> Result<PostCovidResult, RuntimeError> {
    let mut result = PostCovidResult::default();
    debug_assert!(
        records.iter().all(|r| r.pid < num_patients),
        "record pid outside patient space"
    );

    // ---- step 1: candidates from covid → s recurrence + persistence ----
    // durations per (patient, symptom)
    let covid_seqs = util::filter_by_start(records, cfg.covid_phenx);
    let mut durations: BTreeMap<(u32, u32), Vec<u32>> = BTreeMap::new();
    for r in &covid_seqs {
        let (_, end) = decode_seq(r.seq);
        if end == cfg.covid_phenx {
            continue; // covid → covid (reinfection) is not a symptom
        }
        if let Some(filter) = &cfg.candidate_filter {
            if !filter.contains(&end) {
                continue;
            }
        }
        durations.entry((r.pid, end)).or_default().push(r.duration);
    }
    for ((pid, sym), ds) in &durations {
        if ds.len() < cfg.min_occurrences as usize {
            continue;
        }
        let span = ds.iter().max().unwrap() - ds.iter().min().unwrap();
        if span >= cfg.min_duration_span {
            result.candidates.insert((*pid, *sym));
        }
    }

    // ---- step 2: pre-existing exclusion via s → covid sequences ----
    let mut preexisting: BTreeSet<(u32, u32)> = BTreeSet::new();
    for r in records {
        let (start, end) = decode_seq(r.seq);
        if end == cfg.covid_phenx && start != cfg.covid_phenx {
            preexisting.insert((r.pid, start));
        }
    }
    let mut confirmed: BTreeSet<(u32, u32)> = BTreeSet::new();
    for &(pid, sym) in &result.candidates {
        if preexisting.contains(&(pid, sym)) {
            result.excluded.push((pid, sym, sym)); // self-id = pre-existing
        } else {
            confirmed.insert((pid, sym));
        }
    }

    // ---- step 3: alternative-diagnosis exclusion ----
    //
    // For each candidate symptom s, a start phenX x is an *explanation*
    // when (a) patients carry an onsetting persistent x → s pattern
    // (first s within `onset_window` of x, recurring over
    // ≥ min_duration_span), and (b) across the cohort — restricted to
    // patients who have s at all, so mere symptom prevalence cannot
    // masquerade as explanation — carrying that pattern correlates with
    // covid → s candidacy. Carriers of a correlated explanation lose the
    // candidate ("even if it is not causation", as the paper puts it).
    // Which patients carry each code at all (either role) — denominator
    // of the specificity gate.
    let mut pids_with_code: BTreeMap<u32, BTreeSet<u32>> = BTreeMap::new();
    for r in records {
        let (start, end) = decode_seq(r.seq);
        pids_with_code.entry(start).or_default().insert(r.pid);
        pids_with_code.entry(end).or_default().insert(r.pid);
    }

    let symptoms: BTreeSet<u32> = confirmed.iter().map(|&(_, s)| s).collect();
    for sym in symptoms {
        let ending = util::filter_by_end(records, sym);
        // Patients that have the symptom at all (the correlation cohort).
        let mut has_sym: BTreeSet<u32> = ending.iter().map(|r| r.pid).collect();
        has_sym.extend(
            result.candidates.iter().filter(|&&(_, s)| s == sym).map(|&(p, _)| p),
        );
        let cohort: Vec<u32> = has_sym.into_iter().collect();
        if cohort.len() < cfg.min_support as usize {
            continue;
        }
        let row_of: BTreeMap<u32, usize> =
            cohort.iter().enumerate().map(|(i, &p)| (p, i)).collect();

        // Persistent, onsetting x → sym patterns per (x, patient).
        let mut per_start: BTreeMap<u32, BTreeMap<u32, Vec<u32>>> = BTreeMap::new();
        for r in &ending {
            let (start, _) = decode_seq(r.seq);
            if start == cfg.covid_phenx || start == sym {
                continue;
            }
            per_start.entry(start).or_default().entry(r.pid).or_default().push(r.duration);
        }
        let target: Vec<f32> = cohort
            .iter()
            .map(|&p| f32::from(confirmed.contains(&(p, sym))))
            .collect();

        let mut starts: Vec<u32> = Vec::new();
        let mut columns: Vec<Vec<f32>> = Vec::new();
        let mut carriers: Vec<BTreeSet<u32>> = Vec::new();
        for (start, per_pat) in &per_start {
            let mut col = vec![0f32; cohort.len()];
            let mut carrier_set = BTreeSet::new();
            for (pid, ds) in per_pat {
                if ds.len() < cfg.min_occurrences as usize {
                    continue;
                }
                let min = *ds.iter().min().unwrap();
                let span = ds.iter().max().unwrap() - min;
                if span >= cfg.min_duration_span && min <= cfg.onset_window {
                    col[row_of[pid]] = 1.0;
                    carrier_set.insert(*pid);
                }
            }
            if carrier_set.len() < cfg.min_support as usize {
                continue;
            }
            // Specificity gate: most x-carriers must exhibit the pattern.
            let havers = pids_with_code.get(start).map_or(0, |s| s.len());
            let strength = carrier_set.len() as f32 / havers.max(1) as f32;
            if strength >= cfg.strength_min {
                starts.push(*start);
                columns.push(col);
                carriers.push(carrier_set);
            }
        }
        if starts.is_empty() {
            continue;
        }

        // Correlation evidence over the symptom-haver cohort. A constant
        // target (every s-haver is a candidate) carries no signal either
        // way; the specificity gate alone then decides.
        let target_constant = target.iter().all(|&t| t == target[0]);
        let corrs = correlate(&columns, &target, artifacts)?;
        for ((start, corr), carrier_set) in starts.iter().zip(&corrs).zip(&carriers) {
            if target_constant || *corr >= cfg.corr_threshold {
                for &pid in carrier_set {
                    if confirmed.remove(&(pid, sym)) {
                        result.excluded.push((pid, sym, *start));
                    }
                }
            }
        }
    }

    result.confirmed = confirmed;
    Ok(result)
}

/// Pearson correlation of each column with the target over all patients.
/// Uses the `corr_masked` PJRT artifact when available (padding columns
/// to feature tiles and rows to patient tiles), else pure Rust.
fn correlate(
    columns: &[Vec<f32>],
    target: &[f32],
    artifacts: Option<&ArtifactSet>,
) -> Result<Vec<f32>, RuntimeError> {
    match artifacts {
        Some(arts) => correlate_pjrt(columns, target, arts),
        None => Ok(columns.iter().map(|c| pearson(c, target)).collect()),
    }
}

/// Pure-Rust Pearson correlation (oracle for the artifact path).
pub fn pearson(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    let n = a.len() as f64;
    if n == 0.0 {
        return 0.0;
    }
    let ma = a.iter().map(|&v| v as f64).sum::<f64>() / n;
    let mb = b.iter().map(|&v| v as f64).sum::<f64>() / n;
    let mut cov = 0f64;
    let mut va = 0f64;
    let mut vb = 0f64;
    for (&x, &y) in a.iter().zip(b) {
        let dx = x as f64 - ma;
        let dy = y as f64 - mb;
        cov += dx * dy;
        va += dx * dx;
        vb += dy * dy;
    }
    if va <= 1e-12 || vb <= 1e-12 {
        0.0
    } else {
        (cov / (va.sqrt() * vb.sqrt())) as f32
    }
}

fn correlate_pjrt(
    columns: &[Vec<f32>],
    target: &[f32],
    arts: &ArtifactSet,
) -> Result<Vec<f32>, RuntimeError> {
    let (tp, tf) = (arts.tile_rows, arts.tile_features);
    let n_pat = target.len();
    if n_pat > tp {
        // The correlation artifact is single-tile (it needs global means);
        // bigger cohorts use the exact Rust path. A multi-tile masked
        // moment accumulation is a possible artifact extension.
        return Ok(columns.iter().map(|c| pearson(c, target)).collect());
    }
    let art = arts.get("corr_masked")?;
    let mut t = vec![0f32; tp];
    let mut mask = vec![0f32; tp];
    t[..n_pat].copy_from_slice(target);
    mask[..n_pat].fill(1.0);
    let t = Tensor::new(vec![tp, 1], t);
    let mask = Tensor::new(vec![tp, 1], mask);

    let mut out = Vec::with_capacity(columns.len());
    for group in columns.chunks(tf) {
        let mut x = vec![0f32; tp * tf];
        for (j, col) in group.iter().enumerate() {
            for (i, &v) in col.iter().enumerate() {
                x[i * tf + j] = v;
            }
        }
        let r = art.run(&[Tensor::new(vec![tp, tf], x), t.clone(), mask.clone()])?;
        out.extend(r[0].data[..group.len()].iter().copied());
    }
    Ok(out)
}

/// Compare a result against generator ground truth (string-keyed).
pub fn validate(
    result: &PostCovidResult,
    truth: &crate::synthea::GroundTruth,
    lookup: &crate::dbmart::LookupTables,
) -> Validation {
    let confirmed: BTreeSet<(String, String)> = result
        .confirmed
        .iter()
        .map(|&(pid, sym)| {
            (lookup.patient_name(pid).to_string(), lookup.phenx_name(sym).to_string())
        })
        .collect();
    let mut v = Validation::default();
    for pair in &confirmed {
        if truth.postcovid.contains(pair) {
            v.true_positives += 1;
        } else {
            v.false_positives += 1;
        }
    }
    for pair in &truth.postcovid {
        if !confirmed.contains(pair) {
            v.false_negatives += 1;
        }
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dbmart::{encode_seq, NumericDbMart};
    use crate::mining::{mine_sequences, MiningConfig};
    use crate::synthea::{SyntheaConfig, COVID_CODE, SYMPTOM_CODES};

    fn rec(start: u32, end: u32, pid: u32, duration: u32) -> SeqRecord {
        SeqRecord { seq: encode_seq(start, end), pid, duration }
    }

    const COVID: u32 = 0;
    const SYM: u32 = 1;
    const ALT: u32 = 2;

    #[test]
    fn recurrent_persistent_symptom_is_candidate() {
        let records = vec![rec(COVID, SYM, 7, 90), rec(COVID, SYM, 7, 160)];
        let cfg = PostCovidConfig::new(COVID);
        let got = identify(&records, 10, &cfg, None).unwrap();
        assert!(got.confirmed.contains(&(7, SYM)));
    }

    #[test]
    fn single_occurrence_is_not_candidate() {
        let records = vec![rec(COVID, SYM, 7, 90)];
        let got = identify(&records, 10, &PostCovidConfig::new(COVID), None).unwrap();
        assert!(got.confirmed.is_empty());
    }

    #[test]
    fn short_span_is_not_candidate() {
        // two occurrences only 30 days apart — not "ongoing ≥ 2 months"
        let records = vec![rec(COVID, SYM, 7, 90), rec(COVID, SYM, 7, 120)];
        let got = identify(&records, 10, &PostCovidConfig::new(COVID), None).unwrap();
        assert!(got.confirmed.is_empty());
    }

    #[test]
    fn preexisting_symptom_is_excluded() {
        let records = vec![
            rec(SYM, COVID, 7, 30), // symptom BEFORE infection
            rec(COVID, SYM, 7, 90),
            rec(COVID, SYM, 7, 160),
        ];
        let got = identify(&records, 10, &PostCovidConfig::new(COVID), None).unwrap();
        assert!(got.candidates.contains(&(7, SYM)));
        assert!(got.confirmed.is_empty());
        assert_eq!(got.excluded, vec![(7, SYM, SYM)]);
    }

    #[test]
    fn alternative_diagnosis_excludes_correlated_patients() {
        // Patients 0..4: ALT → SYM persistent pattern AND covid → SYM
        // candidacy (the confounder family). Patient 9: true post-covid
        // without ALT. Correlation of ALT-carriage with candidacy is
        // high → patients 0..4 excluded, patient 9 kept.
        let mut records = Vec::new();
        for pid in 0..5u32 {
            records.push(rec(COVID, SYM, pid, 70));
            records.push(rec(COVID, SYM, pid, 150));
            records.push(rec(ALT, SYM, pid, 10));
            records.push(rec(ALT, SYM, pid, 90));
        }
        records.push(rec(COVID, SYM, 9, 80));
        records.push(rec(COVID, SYM, 9, 170));
        let got = identify(&records, 10, &PostCovidConfig::new(COVID), None).unwrap();
        assert_eq!(got.confirmed, BTreeSet::from([(9, SYM)]));
        assert_eq!(got.excluded.len(), 5);
        assert!(got.excluded.iter().all(|&(_, s, x)| s == SYM && x == ALT));
    }

    #[test]
    fn candidate_filter_restricts_ends() {
        let records = vec![
            rec(COVID, SYM, 7, 90),
            rec(COVID, SYM, 7, 160),
            rec(COVID, 5, 7, 90),
            rec(COVID, 5, 7, 160),
        ];
        let mut cfg = PostCovidConfig::new(COVID);
        cfg.candidate_filter = Some(BTreeSet::from([SYM]));
        let got = identify(&records, 10, &cfg, None).unwrap();
        assert!(got.confirmed.contains(&(7, SYM)));
        assert!(!got.confirmed.contains(&(7, 5)));
    }

    #[test]
    fn pearson_basics() {
        assert!((pearson(&[1.0, 2.0, 3.0], &[2.0, 4.0, 6.0]) - 1.0).abs() < 1e-6);
        assert!((pearson(&[1.0, 2.0, 3.0], &[3.0, 2.0, 1.0]) + 1.0).abs() < 1e-6);
        assert_eq!(pearson(&[1.0, 1.0], &[1.0, 2.0]), 0.0); // constant side
    }

    #[test]
    fn end_to_end_on_synthetic_cohort_beats_baseline() {
        // The real validation: mine the synthetic COVID cohort, run the
        // WHO definition, compare against ground truth.
        let cfg = SyntheaConfig::small();
        let g = cfg.generate_with_truth();
        let db = NumericDbMart::encode(&g.dbmart);
        let mined = mine_sequences(&db, &MiningConfig::default()).unwrap();

        let covid = db.lookup.phenx_id(COVID_CODE).expect("covid code present");
        let mut pc_cfg = PostCovidConfig::new(covid);
        pc_cfg.candidate_filter = Some(
            SYMPTOM_CODES.iter().filter_map(|s| db.lookup.phenx_id(s)).collect(),
        );
        let result = identify(&mined.records, db.num_patients() as u32, &pc_cfg, None).unwrap();
        let v = validate(&result, &g.truth, &db.lookup);
        // All planted post-covid trajectories recur ≥3× over ≥60 days →
        // full recall is required; precision suffers only from planted
        // confounders that slip the exclusion rules.
        assert!(v.recall() >= 0.95, "recall {}", v.recall());
        assert!(v.precision() >= 0.6, "precision {}", v.precision());
    }
}
