//! Command-line parsing substrate (the `clap` stand-in).
//!
//! Supports the subset of conventions the `tspm` launcher needs:
//! subcommands, `--flag`, `--key value`, `--key=value`, positional
//! arguments, typed accessors with defaults, required-argument errors, and
//! an auto-generated usage string.

use std::collections::BTreeMap;
use std::fmt;

/// Declarative specification of one option.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    /// `true` if the option is a boolean flag (takes no value).
    pub is_flag: bool,
    /// Default value rendered in help; `None` means required or flag.
    pub default: Option<&'static str>,
    pub required: bool,
}

impl OptSpec {
    pub fn value(name: &'static str, default: Option<&'static str>, help: &'static str) -> Self {
        OptSpec { name, help, is_flag: false, default, required: false }
    }

    pub fn required(name: &'static str, help: &'static str) -> Self {
        OptSpec { name, help, is_flag: false, default: None, required: true }
    }

    pub fn flag(name: &'static str, help: &'static str) -> Self {
        OptSpec { name, help, is_flag: true, default: None, required: false }
    }
}

/// Parse / validation error.
#[derive(Debug, Clone)]
pub struct CliError(pub String);

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for CliError {}

/// Parsed arguments for one (sub)command.
#[derive(Debug, Default, Clone)]
pub struct Args {
    values: BTreeMap<String, String>,
    /// Every explicitly passed value, in command-line order — backs
    /// repeatable options ([`Args::get_all`]); `values` keeps only the
    /// last occurrence.
    multi: BTreeMap<String, Vec<String>>,
    flags: Vec<String>,
    /// Option names the user explicitly passed (defaults excluded).
    provided: Vec<String>,
    pub positionals: Vec<String>,
}

impl Args {
    /// Parse `argv` (without the program/subcommand name) against `spec`.
    pub fn parse(argv: &[String], spec: &[OptSpec]) -> Result<Args, CliError> {
        let mut args = Args::default();
        let find = |name: &str| spec.iter().find(|o| o.name == name);
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if let Some(stripped) = tok.strip_prefix("--") {
                let (name, inline_val) = match stripped.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (stripped, None),
                };
                let opt = find(name)
                    .ok_or_else(|| CliError(format!("unknown option --{name}")))?;
                args.provided.push(name.to_string());
                if opt.is_flag {
                    if inline_val.is_some() {
                        return Err(CliError(format!("flag --{name} takes no value")));
                    }
                    args.flags.push(name.to_string());
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| CliError(format!("--{name} needs a value")))?
                        }
                    };
                    args.multi.entry(name.to_string()).or_default().push(val.clone());
                    args.values.insert(name.to_string(), val);
                }
            } else {
                args.positionals.push(tok.clone());
            }
            i += 1;
        }
        for opt in spec {
            if opt.required && !args.values.contains_key(opt.name) {
                return Err(CliError(format!("missing required option --{}", opt.name)));
            }
            if let (Some(d), false) = (opt.default, args.values.contains_key(opt.name)) {
                args.values.insert(opt.name.to_string(), d.to_string());
            }
        }
        Ok(args)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Whether the user explicitly passed `--name` (as opposed to the
    /// option resolving through its default). Lets subcommands with
    /// mutually exclusive selectors — `tspm query --seq|--pid|--top-k`
    /// — distinguish "given" from "defaulted".
    pub fn provided(&self, name: &str) -> bool {
        self.provided.iter().any(|p| p == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    /// Every value of a repeatable option, in command-line order —
    /// `--index-dir a --index-dir b` yields `["a", "b"]`. Falls back to
    /// the default (as a singleton) when the user passed nothing and
    /// the spec declared one; empty otherwise.
    pub fn get_all(&self, name: &str) -> Vec<&str> {
        match self.multi.get(name) {
            Some(vals) => vals.iter().map(|s| s.as_str()).collect(),
            None => self.get(name).map(|v| vec![v]).unwrap_or_default(),
        }
    }

    pub fn get_parsed<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>, CliError> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|_| CliError(format!("invalid value for --{name}: {v:?}"))),
        }
    }

    /// Typed accessor that must resolve (option had a default or was given).
    pub fn req<T: std::str::FromStr>(&self, name: &str) -> Result<T, CliError> {
        self.get_parsed::<T>(name)?
            .ok_or_else(|| CliError(format!("missing --{name}")))
    }
}

/// Render a usage/help block for a subcommand.
pub fn usage(cmd: &str, about: &str, spec: &[OptSpec]) -> String {
    let mut out = format!("{cmd} — {about}\n\noptions:\n");
    for o in spec {
        let head = if o.is_flag {
            format!("  --{}", o.name)
        } else {
            format!("  --{} <value>", o.name)
        };
        let mut line = format!("{head:<32} {}", o.help);
        if let Some(d) = o.default {
            line.push_str(&format!(" [default: {d}]"));
        }
        if o.required {
            line.push_str(" [required]");
        }
        out.push_str(&line);
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> Vec<OptSpec> {
        vec![
            OptSpec::value("patients", Some("100"), "cohort size"),
            OptSpec::required("out", "output path"),
            OptSpec::flag("verbose", "noisy logging"),
            OptSpec::value("mode", Some("memory"), "memory|file"),
        ]
    }

    fn sv(items: &[&str]) -> Vec<String> {
        items.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_key_value_and_flags() {
        let a = Args::parse(
            &sv(&["--patients", "500", "--out=/tmp/x", "--verbose", "pos1"]),
            &spec(),
        )
        .unwrap();
        assert_eq!(a.req::<u64>("patients").unwrap(), 500);
        assert_eq!(a.get("out"), Some("/tmp/x"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.positionals, vec!["pos1"]);
    }

    #[test]
    fn defaults_applied() {
        let a = Args::parse(&sv(&["--out", "o"]), &spec()).unwrap();
        assert_eq!(a.req::<u64>("patients").unwrap(), 100);
        assert_eq!(a.get("mode"), Some("memory"));
    }

    #[test]
    fn provided_distinguishes_explicit_from_default() {
        let a = Args::parse(&sv(&["--out", "o", "--verbose"]), &spec()).unwrap();
        assert!(a.provided("out"));
        assert!(a.provided("verbose"));
        // "patients" resolved through its default: get() answers, but it
        // was never on the command line.
        assert_eq!(a.get("patients"), Some("100"));
        assert!(!a.provided("patients"));
        assert!(!a.provided("mode"));
    }

    #[test]
    fn repeated_options_accumulate_in_order() {
        let a = Args::parse(
            &sv(&["--out", "a", "--out=b", "--out", "c", "--patients", "5"]),
            &spec(),
        )
        .unwrap();
        assert_eq!(a.get_all("out"), vec!["a", "b", "c"]);
        // Scalar accessors keep last-one-wins semantics.
        assert_eq!(a.get("out"), Some("c"));
        // An un-passed option with a default answers as a singleton…
        assert_eq!(a.get_all("patients"), vec!["5"]);
        assert_eq!(a.get_all("mode"), vec!["memory"]);
        // …and one with neither value nor default is empty.
        let b = Args::parse(&sv(&["--out", "o"]), &spec()).unwrap();
        assert!(b.get_all("nope").is_empty());
    }

    #[test]
    fn missing_required_rejected() {
        let err = Args::parse(&sv(&["--patients", "5"]), &spec()).unwrap_err();
        assert!(err.0.contains("--out"));
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(Args::parse(&sv(&["--nope", "1", "--out", "o"]), &spec()).is_err());
    }

    #[test]
    fn value_option_missing_value_rejected() {
        assert!(Args::parse(&sv(&["--out"]), &spec()).is_err());
    }

    #[test]
    fn flag_with_value_rejected() {
        assert!(Args::parse(&sv(&["--verbose=1", "--out", "o"]), &spec()).is_err());
    }

    #[test]
    fn bad_typed_value_rejected() {
        let a = Args::parse(&sv(&["--patients", "abc", "--out", "o"]), &spec()).unwrap();
        assert!(a.req::<u64>("patients").is_err());
    }

    #[test]
    fn usage_mentions_all_options() {
        let u = usage("mine", "mine sequences", &spec());
        for name in ["patients", "out", "verbose", "mode"] {
            assert!(u.contains(name));
        }
        assert!(u.contains("[required]"));
        assert!(u.contains("[default: 100]"));
    }
}
