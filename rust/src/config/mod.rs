//! Run configuration: a single JSON-backed config object shared by the CLI,
//! examples and benchmarks.
//!
//! The original system spreads configuration over R function arguments;
//! here a [`RunConfig`] captures the full pipeline surface (workload,
//! mining, sparsity, partitioning, artifact paths) with validated loading
//! from JSON and round-trip serialization, so every experiment is
//! reproducible from a checked-in config file.

use crate::engine::{BackendChoice, OutputChoice};
use crate::json::Json;
use crate::mining::{MiningConfig, MiningMode};
use crate::sparsity::SparsityConfig;
use crate::target::{TargetPos, TargetSpec};
use std::fmt;
use std::path::{Path, PathBuf};

/// Errors from loading/validating a config.
#[derive(Debug)]
pub struct ConfigError(pub String);

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config error: {}", self.0)
    }
}

impl std::error::Error for ConfigError {}

/// Full pipeline configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct RunConfig {
    // --- workload ---
    /// Number of synthetic patients to generate (when no input file given).
    pub patients: u64,
    /// Target average entries per patient.
    pub avg_entries: f64,
    /// Number of distinct phenX codes in the vocabulary.
    pub vocab_size: u64,
    /// RNG seed for workload generation.
    pub seed: u64,
    // --- mining ---
    /// Worker threads (0 = auto).
    pub threads: usize,
    /// Keep only the first occurrence of each phenX per patient
    /// (the paper's comparison-benchmark protocol).
    pub first_occurrence_only: bool,
    /// `memory` or `file` operating mode.
    pub mode: String,
    /// Engine execution backend: `auto`, `memory`, `sharded`, `file` or
    /// `streaming` (see [`crate::engine::BackendChoice`]). `auto` defers
    /// to the engine's memory forecast and worker count, except that
    /// `mode = "file"` pins the file-backed backend for backwards
    /// compatibility.
    pub backend: String,
    /// Shard count for the sharded backend (0 = auto:
    /// [`crate::mining::DEFAULT_SHARDS`], a layout independent of the
    /// worker count).
    pub shards: usize,
    /// Engine result residency: `auto`, `memory` or `spilled` (see
    /// [`crate::engine::OutputChoice`]). `auto` spills the result to
    /// disk when the post-screen forecast exceeds the memory budget on
    /// an out-of-core backend.
    pub output: String,
    /// Duration unit divisor in days (1 = days, 7 = weeks, 30 = months).
    pub duration_unit_days: u32,
    // --- sparsity ---
    /// Apply the sparsity screen after mining.
    pub sparsity_screen: bool,
    /// Minimum number of distinct patients a sequence must occur in.
    pub sparsity_min_patients: u32,
    // --- targeting ---
    /// PhenX code *names* the run is targeted to (empty = mine everything).
    /// Resolved against the cohort's vocabulary when the engine is built;
    /// unknown names are rejected before mining starts.
    pub target_codes: Vec<String>,
    /// Which end of a mined pair a target code must occupy:
    /// `first`, `second` or `either`.
    pub target_pos: String,
    /// Inclusive lower bound on the encoded duration (`null` = unbounded).
    pub target_dur_min: Option<u32>,
    /// Inclusive upper bound on the encoded duration (`null` = unbounded).
    pub target_dur_max: Option<u32>,
    // --- partitioning ---
    /// Cap on elements per chunk (paper: R's 2^31-1 vector limit).
    pub max_elements_per_chunk: u64,
    // --- paths ---
    /// Directory holding AOT-compiled HLO artifacts.
    pub artifacts_dir: String,
    /// Scratch directory for file-based mode.
    pub work_dir: String,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            patients: 1000,
            avg_entries: 400.0,
            vocab_size: 5_000,
            seed: 20231107,
            threads: 0,
            first_occurrence_only: false,
            mode: "memory".to_string(),
            backend: "auto".to_string(),
            shards: 0,
            output: "auto".to_string(),
            duration_unit_days: 1,
            sparsity_screen: true,
            sparsity_min_patients: 50,
            target_codes: Vec::new(),
            target_pos: "either".to_string(),
            target_dur_min: None,
            target_dur_max: None,
            max_elements_per_chunk: (1u64 << 31) - 1,
            artifacts_dir: "artifacts".to_string(),
            work_dir: "/tmp/tspm_work".to_string(),
        }
    }
}

impl RunConfig {
    /// Serialize to pretty JSON.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("patients", Json::from(self.patients)),
            ("avg_entries", Json::from(self.avg_entries)),
            ("vocab_size", Json::from(self.vocab_size)),
            ("seed", Json::from(self.seed)),
            ("threads", Json::from(self.threads)),
            ("first_occurrence_only", Json::from(self.first_occurrence_only)),
            ("mode", Json::from(self.mode.clone())),
            ("backend", Json::from(self.backend.clone())),
            ("shards", Json::from(self.shards)),
            ("output", Json::from(self.output.clone())),
            ("duration_unit_days", Json::from(self.duration_unit_days as u64)),
            ("sparsity_screen", Json::from(self.sparsity_screen)),
            ("sparsity_min_patients", Json::from(self.sparsity_min_patients as u64)),
            (
                "target_codes",
                Json::Arr(self.target_codes.iter().map(|c| Json::from(c.clone())).collect()),
            ),
            ("target_pos", Json::from(self.target_pos.clone())),
            (
                "target_dur_min",
                self.target_dur_min.map_or(Json::Null, |v| Json::from(v as u64)),
            ),
            (
                "target_dur_max",
                self.target_dur_max.map_or(Json::Null, |v| Json::from(v as u64)),
            ),
            ("max_elements_per_chunk", Json::from(self.max_elements_per_chunk)),
            ("artifacts_dir", Json::from(self.artifacts_dir.clone())),
            ("work_dir", Json::from(self.work_dir.clone())),
        ])
    }

    /// Parse from a JSON value; unknown keys are rejected (typo guard),
    /// missing keys fall back to defaults.
    pub fn from_json(j: &Json) -> Result<RunConfig, ConfigError> {
        let obj = j.as_obj().ok_or_else(|| ConfigError("top level must be an object".into()))?;
        let known = [
            "patients", "avg_entries", "vocab_size", "seed", "threads",
            "first_occurrence_only", "mode", "backend", "shards", "output",
            "duration_unit_days", "sparsity_screen", "sparsity_min_patients",
            "target_codes", "target_pos", "target_dur_min", "target_dur_max",
            "max_elements_per_chunk", "artifacts_dir", "work_dir",
        ];
        for k in obj.keys() {
            if !known.contains(&k.as_str()) {
                return Err(ConfigError(format!("unknown config key {k:?}")));
            }
        }
        let mut c = RunConfig::default();
        macro_rules! get_u64 {
            ($field:ident, $key:literal) => {
                if let Some(v) = j.get($key) {
                    c.$field = v
                        .as_u64()
                        .ok_or_else(|| ConfigError(format!("{} must be a non-negative integer", $key)))?
                        as _;
                }
            };
        }
        get_u64!(patients, "patients");
        get_u64!(vocab_size, "vocab_size");
        get_u64!(seed, "seed");
        get_u64!(threads, "threads");
        get_u64!(shards, "shards");
        get_u64!(duration_unit_days, "duration_unit_days");
        get_u64!(sparsity_min_patients, "sparsity_min_patients");
        get_u64!(max_elements_per_chunk, "max_elements_per_chunk");
        if let Some(v) = j.get("avg_entries") {
            c.avg_entries = v
                .as_f64()
                .ok_or_else(|| ConfigError("avg_entries must be a number".into()))?;
        }
        if let Some(v) = j.get("first_occurrence_only") {
            c.first_occurrence_only =
                v.as_bool().ok_or_else(|| ConfigError("first_occurrence_only must be a bool".into()))?;
        }
        if let Some(v) = j.get("sparsity_screen") {
            c.sparsity_screen =
                v.as_bool().ok_or_else(|| ConfigError("sparsity_screen must be a bool".into()))?;
        }
        if let Some(v) = j.get("mode") {
            c.mode = v.as_str().ok_or_else(|| ConfigError("mode must be a string".into()))?.to_string();
        }
        if let Some(v) = j.get("backend") {
            c.backend =
                v.as_str().ok_or_else(|| ConfigError("backend must be a string".into()))?.to_string();
        }
        if let Some(v) = j.get("output") {
            c.output =
                v.as_str().ok_or_else(|| ConfigError("output must be a string".into()))?.to_string();
        }
        if let Some(v) = j.get("artifacts_dir") {
            c.artifacts_dir =
                v.as_str().ok_or_else(|| ConfigError("artifacts_dir must be a string".into()))?.to_string();
        }
        if let Some(v) = j.get("work_dir") {
            c.work_dir =
                v.as_str().ok_or_else(|| ConfigError("work_dir must be a string".into()))?.to_string();
        }
        if let Some(v) = j.get("target_codes") {
            let arr = v
                .as_arr()
                .ok_or_else(|| ConfigError("target_codes must be an array of strings".into()))?;
            c.target_codes = arr
                .iter()
                .map(|e| {
                    e.as_str()
                        .map(str::to_string)
                        .ok_or_else(|| ConfigError("target_codes must be an array of strings".into()))
                })
                .collect::<Result<Vec<_>, _>>()?;
        }
        if let Some(v) = j.get("target_pos") {
            c.target_pos =
                v.as_str().ok_or_else(|| ConfigError("target_pos must be a string".into()))?.to_string();
        }
        if let Some(v) = j.get("target_dur_min") {
            if !matches!(v, Json::Null) {
                c.target_dur_min = Some(
                    v.as_u64()
                        .ok_or_else(|| ConfigError("target_dur_min must be a non-negative integer".into()))?
                        as u32,
                );
            }
        }
        if let Some(v) = j.get("target_dur_max") {
            if !matches!(v, Json::Null) {
                c.target_dur_max = Some(
                    v.as_u64()
                        .ok_or_else(|| ConfigError("target_dur_max must be a non-negative integer".into()))?
                        as u32,
                );
            }
        }
        c.validate()?;
        Ok(c)
    }

    /// Load from a JSON file.
    pub fn load(path: &Path) -> Result<RunConfig, ConfigError> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| ConfigError(format!("cannot read {}: {e}", path.display())))?;
        let j = Json::parse(&text).map_err(|e| ConfigError(e.to_string()))?;
        Self::from_json(&j)
    }

    /// Save to a JSON file.
    pub fn save(&self, path: &Path) -> Result<(), ConfigError> {
        std::fs::write(path, self.to_json().to_string_pretty())
            .map_err(|e| ConfigError(format!("cannot write {}: {e}", path.display())))
    }

    /// Semantic validation.
    pub fn validate(&self) -> Result<(), ConfigError> {
        if self.mode != "memory" && self.mode != "file" {
            return Err(ConfigError(format!("mode must be 'memory' or 'file', got {:?}", self.mode)));
        }
        if let Err(e) = self.backend.parse::<BackendChoice>() {
            return Err(ConfigError(e));
        }
        if let Err(e) = self.output.parse::<OutputChoice>() {
            return Err(ConfigError(e));
        }
        if self.patients == 0 {
            return Err(ConfigError("patients must be > 0".into()));
        }
        if self.avg_entries <= 0.0 {
            return Err(ConfigError("avg_entries must be > 0".into()));
        }
        if self.vocab_size == 0 || self.vocab_size >= crate::dbmart::MAX_PHENX as u64 {
            return Err(ConfigError(format!(
                "vocab_size must be in 1..{} (7-decimal-digit phenX encoding)",
                crate::dbmart::MAX_PHENX
            )));
        }
        if self.duration_unit_days == 0 {
            return Err(ConfigError("duration_unit_days must be > 0".into()));
        }
        if self.max_elements_per_chunk == 0 {
            return Err(ConfigError("max_elements_per_chunk must be > 0".into()));
        }
        if self.shards > crate::mining::MAX_SHARDS {
            return Err(ConfigError(format!(
                "shards must be ≤ {} (0 = auto), got {}",
                crate::mining::MAX_SHARDS,
                self.shards
            )));
        }
        if let Err(e) = self.target_pos.parse::<TargetPos>() {
            return Err(ConfigError(e));
        }
        if let (Some(lo), Some(hi)) = (self.target_dur_min, self.target_dur_max) {
            if lo > hi {
                return Err(ConfigError(format!(
                    "target duration band is inverted: min {lo} > max {hi}"
                )));
            }
        }
        if self.target_codes.iter().any(|c| c.is_empty()) {
            return Err(ConfigError("target_codes entries must be non-empty names".into()));
        }
        Ok(())
    }

    // --- engine wiring -----------------------------------------------------

    /// The mining stage configuration this config describes.
    pub fn mining_config(&self) -> MiningConfig {
        MiningConfig {
            threads: self.threads,
            first_occurrence_only: self.first_occurrence_only,
            duration_unit_days: self.duration_unit_days,
            mode: if self.mode == "file" { MiningMode::FileBased } else { MiningMode::InMemory },
            work_dir: PathBuf::from(&self.work_dir),
            include_self_pairs: true,
            shards: self.shards,
        }
    }

    /// The sparsity-screen stage, when `sparsity_screen` is enabled.
    /// A threshold of 0 keeps every sequence, so it counts as disabled
    /// (old configs with `sparsity_min_patients: 0` stay loadable).
    pub fn sparsity_config(&self) -> Option<SparsityConfig> {
        (self.sparsity_screen && self.sparsity_min_patients > 0).then_some(SparsityConfig {
            min_patients: self.sparsity_min_patients,
            threads: self.threads,
        })
    }

    /// The engine backend this config requests. `auto` stays automatic
    /// unless the legacy `mode = "file"` pins file-backed execution.
    ///
    /// Unparsable names are an error — they used to map silently to
    /// `Auto`, so callers that skipped [`RunConfig::validate`] ran the
    /// wrong backend without any diagnostic.
    pub fn backend_choice(&self) -> Result<BackendChoice, ConfigError> {
        let choice = self.backend.parse::<BackendChoice>().map_err(ConfigError)?;
        Ok(match choice {
            BackendChoice::Auto if self.mode == "file" => BackendChoice::FileBacked,
            other => other,
        })
    }

    /// The engine result residency this config requests; unparsable
    /// names are an error, mirroring [`RunConfig::backend_choice`].
    pub fn output_choice(&self) -> Result<OutputChoice, ConfigError> {
        self.output.parse::<OutputChoice>().map_err(ConfigError)
    }

    /// Build the [`TargetSpec`] this config describes, resolving code
    /// *names* to encoded phenX ids via `resolve` (usually
    /// `|name| db.lookup.phenx_id(name)`). Returns `Ok(None)` when the
    /// config requests no targeting at all; unknown names error with the
    /// offending name, not a bare id.
    pub fn target_spec_with(
        &self,
        resolve: impl Fn(&str) -> Option<u32>,
    ) -> Result<Option<TargetSpec>, String> {
        let pos: TargetPos = self.target_pos.parse()?;
        if self.target_codes.is_empty()
            && self.target_dur_min.is_none()
            && self.target_dur_max.is_none()
        {
            return Ok(None);
        }
        let mut spec = if self.target_codes.is_empty() {
            TargetSpec::all()
        } else {
            let mut ids = Vec::with_capacity(self.target_codes.len());
            for name in &self.target_codes {
                ids.push(resolve(name).ok_or_else(|| {
                    format!("target code {name:?} is not in the cohort's vocabulary")
                })?);
            }
            TargetSpec::for_codes(ids)
        };
        spec = spec.with_pos(pos).with_duration_band(self.target_dur_min, self.target_dur_max);
        spec.validate()?;
        Ok(Some(spec))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        RunConfig::default().validate().unwrap();
    }

    #[test]
    fn json_roundtrip() {
        let mut c = RunConfig::default();
        c.patients = 4985;
        c.avg_entries = 471.0;
        c.mode = "file".into();
        c.sparsity_screen = false;
        let j = c.to_json();
        let back = RunConfig::from_json(&j).unwrap();
        assert_eq!(back, c);
    }

    #[test]
    fn unknown_key_rejected() {
        let j = Json::parse(r#"{"patiens": 5}"#).unwrap();
        let err = RunConfig::from_json(&j).unwrap_err();
        assert!(err.0.contains("patiens"));
    }

    #[test]
    fn bad_mode_rejected() {
        let j = Json::parse(r#"{"mode": "gpu"}"#).unwrap();
        assert!(RunConfig::from_json(&j).is_err());
    }

    #[test]
    fn bad_backend_rejected() {
        let j = Json::parse(r#"{"backend": "quantum"}"#).unwrap();
        assert!(RunConfig::from_json(&j).is_err());
    }

    #[test]
    fn backend_choice_mapping() {
        let mut c = RunConfig::default();
        assert_eq!(c.backend_choice().unwrap(), BackendChoice::Auto);
        c.backend = "streaming".into();
        assert_eq!(c.backend_choice().unwrap(), BackendChoice::Streaming);
        c.backend = "memory".into();
        assert_eq!(c.backend_choice().unwrap(), BackendChoice::InMemory);
        c.backend = "sharded".into();
        assert_eq!(c.backend_choice().unwrap(), BackendChoice::Sharded);
        // Legacy file mode pins the file-backed backend under auto.
        c.backend = "auto".into();
        c.mode = "file".into();
        assert_eq!(c.backend_choice().unwrap(), BackendChoice::FileBacked);
    }

    #[test]
    fn unparsable_backend_is_an_error_not_auto() {
        // Regression: callers that skip validate() used to fall back to
        // Auto silently and run the wrong backend.
        let mut c = RunConfig::default();
        c.backend = "quantum".into();
        let err = c.backend_choice().unwrap_err();
        assert!(err.to_string().contains("quantum"), "got {err}");
    }

    #[test]
    fn output_choice_parses_and_round_trips() {
        let mut c = RunConfig::default();
        assert_eq!(c.output_choice().unwrap(), OutputChoice::Auto);
        c.output = "spilled".into();
        assert_eq!(c.output_choice().unwrap(), OutputChoice::Spilled);
        let back = RunConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back, c);
        c.output = "ram".into();
        assert!(c.output_choice().is_err());
        assert!(c.validate().is_err());
        let j = Json::parse(r#"{"output": "ram"}"#).unwrap();
        assert!(RunConfig::from_json(&j).is_err());
    }

    #[test]
    fn zero_threshold_counts_as_screen_disabled() {
        // Seed-era configs could carry min_patients 0 with the screen on
        // (a no-op); they must stay loadable and simply skip the stage.
        let j = Json::parse(r#"{"sparsity_screen": true, "sparsity_min_patients": 0}"#).unwrap();
        let c = RunConfig::from_json(&j).unwrap();
        assert!(c.sparsity_config().is_none());
    }

    #[test]
    fn shards_roundtrip_and_validation() {
        let mut c = RunConfig::default();
        c.backend = "sharded".into();
        c.shards = 12;
        let back = RunConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back, c);
        assert_eq!(back.mining_config().shards, 12);

        let j = Json::parse(r#"{"shards": 99999999}"#).unwrap();
        let err = RunConfig::from_json(&j).unwrap_err();
        assert!(err.0.contains("shards"), "got {}", err.0);
    }

    #[test]
    fn mining_and_sparsity_wiring() {
        let mut c = RunConfig::default();
        c.mode = "file".into();
        c.duration_unit_days = 7;
        let mc = c.mining_config();
        assert!(matches!(mc.mode, MiningMode::FileBased));
        assert_eq!(mc.duration_unit_days, 7);
        assert_eq!(c.sparsity_config().unwrap().min_patients, c.sparsity_min_patients);
        c.sparsity_screen = false;
        assert!(c.sparsity_config().is_none());
    }

    #[test]
    fn vocab_limit_enforced() {
        let j = Json::parse(r#"{"vocab_size": 10000000}"#).unwrap();
        assert!(RunConfig::from_json(&j).is_err(), "phenX ids must fit 7 digits");
    }

    #[test]
    fn partial_json_uses_defaults() {
        let j = Json::parse(r#"{"patients": 7}"#).unwrap();
        let c = RunConfig::from_json(&j).unwrap();
        assert_eq!(c.patients, 7);
        assert_eq!(c.vocab_size, RunConfig::default().vocab_size);
    }

    #[test]
    fn target_fields_roundtrip_and_validate() {
        let mut c = RunConfig::default();
        c.target_codes = vec!["C9".into(), "C3".into()];
        c.target_pos = "first".into();
        c.target_dur_max = Some(90);
        c.validate().unwrap();
        let back = RunConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back, c);

        // Old configs without the keys still load (targeting defaults off).
        let j = Json::parse(r#"{"patients": 7}"#).unwrap();
        let old = RunConfig::from_json(&j).unwrap();
        assert!(old.target_codes.is_empty());
        assert!(old.target_spec_with(|_| None).unwrap().is_none());

        // Inverted band and bad position are rejected at validate time.
        let j = Json::parse(r#"{"target_dur_min": 10, "target_dur_max": 2}"#).unwrap();
        assert!(RunConfig::from_json(&j).unwrap_err().0.contains("inverted"));
        let j = Json::parse(r#"{"target_pos": "sideways"}"#).unwrap();
        assert!(RunConfig::from_json(&j).is_err());
    }

    #[test]
    fn target_spec_resolution_names_the_unknown_code() {
        let mut c = RunConfig::default();
        c.target_codes = vec!["flu".into(), "ghost".into()];
        let resolve = |name: &str| (name == "flu").then_some(7u32);
        let err = c.target_spec_with(resolve).unwrap_err();
        assert!(err.contains("ghost"), "got {err}");

        c.target_codes = vec!["flu".into(), "flu".into()];
        c.target_pos = "second".into();
        let spec = c.target_spec_with(resolve).unwrap().unwrap();
        assert_eq!(spec, TargetSpec::for_codes([7]).with_pos(TargetPos::Second));

        // A duration band alone still builds a (codeless) spec.
        c.target_codes.clear();
        c.target_pos = "either".into();
        c.target_dur_max = Some(30);
        let spec = c.target_spec_with(|_| None).unwrap().unwrap();
        assert!(spec.codes().is_none());
        assert!(!spec.is_all());
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("tspm_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cfg.json");
        let c = RunConfig::default();
        c.save(&path).unwrap();
        let back = RunConfig::load(&path).unwrap();
        assert_eq!(back, c);
    }
}
