//! Bench T1 — paper Table 1: the comparison benchmark (tSPM vs tSPM+).
//!
//! Six rows: original tSPM ± sparsity screening, tSPM+ in-memory and
//! file-based ± screening. Workload: the MGB-Biobank-like cohort (4,985
//! patients × ~471 entries at scale 1.0; default scale 0.1 to fit this
//! testbed, override via `TSPM_BENCH_SCALE`). Iterations default to 3
//! (`TSPM_BENCH_ITERS`; the paper uses 10).
//!
//! Prints the paper-style memory/runtime min/max/avg table plus the
//! headline speedup and memory-reduction factors, and writes
//! `bench_results/table1.json`.

use tspm_plus::bench_util::{experiments, rows_to_json};

fn env_f64(key: &str, default: f64) -> f64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let scale = env_f64("TSPM_BENCH_SCALE", 0.1);
    let iters = env_usize("TSPM_BENCH_ITERS", 3);
    eprintln!("table1: scale={scale} iterations={iters} (paper: scale=1.0, 10 iters)");
    let rows = experiments::table1(scale, iters);
    print!("{}", experiments::table1_report(&rows));
    std::fs::create_dir_all("bench_results").ok();
    std::fs::write("bench_results/table1.json", rows_to_json(&rows).to_string_pretty())
        .expect("write bench_results/table1.json");
    eprintln!("wrote bench_results/table1.json");
}
