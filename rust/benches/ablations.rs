//! Bench A1 — ablations of the design choices the paper's Discussion
//! credits for the speedup:
//!
//! 1. **numeric vs string encoding** — "a fraction of the speedup is
//!    achieved by replacing slow string operations … with faster numeric
//!    ones": mine the same cohort through tSPM+ and through the
//!    string-based inner loop, same protocol.
//! 2. **sort-then-scan vs hash screening** — "we at first sorted the
//!    mined sequences by their sequence ID and then just needed to
//!    iterate": the paper's screen vs the naive hash-map screen.
//! 3. **psort vs std sort** — the ips4o-style samplesort substrate vs
//!    Rust's `sort_unstable_by_key` on the mining pre-sort key.
//! 4. **duration packing** — bit-shift packing vs tuple comparison for
//!    duration-aware sorting (the paper's "cheap bitshift operations").

use std::time::Instant;

use tspm_plus::baseline::{self, BaselineConfig};
use tspm_plus::bench_util::{measure, render_table, rows_to_json, RowStats};
use tspm_plus::dbmart::{pack_duration, NumericDbMart};
use tspm_plus::mining::{self, MiningConfig};
use tspm_plus::rng::Rng;
use tspm_plus::sparsity::{self, SparsityConfig};
use tspm_plus::synthea::SyntheaConfig;

fn main() {
    let iters = std::env::var("TSPM_BENCH_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(3);
    let scale = std::env::var("TSPM_BENCH_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.05);
    let gen_cfg = SyntheaConfig::mgb_like(scale);
    let raw = gen_cfg.generate();
    let db = NumericDbMart::encode(&raw);

    // --- ablation 1: numeric vs string encoding --------------------------
    let mut rows = Vec::new();
    rows.push(RowStats::from_samples(
        "A1.1 numeric encoding (tSPM+ inner loop)",
        &measure(iters, || {
            let cfg = MiningConfig { first_occurrence_only: true, ..Default::default() };
            let set = mining::mine_sequences(&db, &cfg).expect("mine");
            std::hint::black_box(set.len());
            set.byte_size()
        }),
    ));
    rows.push(RowStats::from_samples(
        "A1.1 string encoding (baseline inner loop)",
        &measure(iters, || {
            let r = baseline::mine(
                &raw,
                &BaselineConfig { first_occurrence_only: true, ..Default::default() },
            );
            std::hint::black_box(r.sequences.len());
            r.logical_bytes
        }),
    ));

    // --- ablation 2: sort-then-scan vs hash screening ---------------------
    let mined = mining::mine_sequences(
        &db,
        &MiningConfig { first_occurrence_only: true, ..Default::default() },
    )
    .expect("mine");
    let threshold = (gen_cfg.patients / 100).max(2) as u32;
    rows.push(RowStats::from_samples(
        "A1.2 screen: radix sort + compaction (ours)",
        &measure(iters, || {
            let mut records = mined.records.clone();
            sparsity::screen(
                &mut records,
                &SparsityConfig { min_patients: threshold, threads: 0 },
            );
            std::hint::black_box(records.len());
            (records.capacity() * 16) as u64
        }),
    ));
    rows.push(RowStats::from_samples(
        "A1.2 screen: sort-mark-truncate (paper)",
        &measure(iters, || {
            let mut records = mined.records.clone();
            sparsity::screen_paper_strategy(
                &mut records,
                &SparsityConfig { min_patients: threshold, threads: 0 },
            );
            std::hint::black_box(records.len());
            (records.capacity() * 16) as u64
        }),
    ));
    rows.push(RowStats::from_samples(
        "A1.2 screen: hash map (naive)",
        &measure(iters, || {
            let mut records = mined.records.clone();
            sparsity::screen_naive(
                &mut records,
                &SparsityConfig { min_patients: threshold, threads: 0 },
            );
            std::hint::black_box(records.len());
            (records.capacity() * 16) as u64
        }),
    ));

    // --- ablation 3: psort vs std sort ------------------------------------
    let sort_input: Vec<u64> = {
        let mut r = Rng::new(99);
        (0..4_000_000).map(|_| r.next_u64()).collect()
    };
    rows.push(RowStats::from_samples(
        "A1.3 sort: psort samplesort",
        &measure(iters, || {
            let mut v = sort_input.clone();
            tspm_plus::psort::par_sort_by_key(&mut v, |x| *x, 4);
            std::hint::black_box(v[0]);
            (v.capacity() * 8) as u64
        }),
    ));
    rows.push(RowStats::from_samples(
        "A1.3 sort: std sort_unstable",
        &measure(iters, || {
            let mut v = sort_input.clone();
            v.sort_unstable();
            std::hint::black_box(v[0]);
            (v.capacity() * 8) as u64
        }),
    ));

    // --- ablation 4: duration packing vs tuple keys ------------------------
    let recs = mined.records.clone();
    rows.push(RowStats::from_samples(
        "A1.4 duration sort: packed u64 key (paper)",
        &measure(iters, || {
            let mut v = recs.clone();
            let t = Instant::now();
            v.sort_unstable_by_key(|r| pack_duration(r.seq, r.duration));
            std::hint::black_box(t.elapsed());
            (v.capacity() * 16) as u64
        }),
    ));
    rows.push(RowStats::from_samples(
        "A1.4 duration sort: (seq, duration) tuple key",
        &measure(iters, || {
            let mut v = recs.clone();
            v.sort_unstable_by_key(|r| (r.seq, r.duration));
            std::hint::black_box(v.len());
            (v.capacity() * 16) as u64
        }),
    ));

    // --- ablation 5: static ranges vs sharded dynamic scheduling ----------
    // Same mine, two schedulers: the in-memory path assigns each worker a
    // fixed cost-balanced range up front; the sharded backend oversubscribes
    // with 4× shards claimed dynamically, so skewed patients can't leave
    // workers idle. The gap is the price of static assignment on this cohort.
    rows.push(RowStats::from_samples(
        "A1.5 mine: static ranges (in-memory backend)",
        &measure(iters, || {
            let cfg = MiningConfig { threads: 4, ..Default::default() };
            let set = mining::mine_sequences(&db, &cfg).expect("mine");
            std::hint::black_box(set.len());
            set.byte_size()
        }),
    ));
    rows.push(RowStats::from_samples(
        "A1.5 mine: dynamic shards (sharded backend)",
        &measure(iters, || {
            let cfg = MiningConfig { threads: 4, ..Default::default() };
            let set = mining::mine_sequences_sharded(&db, &cfg).expect("mine sharded");
            std::hint::black_box(set.len());
            set.byte_size()
        }),
    ));

    print!("{}", render_table("Ablations — design-choice contributions", &rows));
    std::fs::create_dir_all("bench_results").ok();
    std::fs::write("bench_results/ablations.json", rows_to_json(&rows).to_string_pretty())
        .expect("write bench_results/ablations.json");
    eprintln!("wrote bench_results/ablations.json");
}
