//! Bench T3 — paper §Results "Performance on End User devices".
//!
//! The paper: "Even on devices with only 4 to 8 cores and less than 16GB
//! of memory we were able to run the tSPM+ algorithm to sequence more
//! than 1000 patients and ~400 entries per patient in less than 5
//! minutes." This bench runs exactly that workload (1,000 patients ×
//! ~400 entries, with sparsity screening) at 1/2/4 threads and asserts
//! the 5-minute bound.

use tspm_plus::bench_util::{experiments, render_table, rows_to_json};

fn main() {
    let iters = std::env::var("TSPM_BENCH_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(3);
    let rows = experiments::enduser(iters);
    print!(
        "{}",
        render_table("End-user device benchmark (1k patients × ~400 entries)", &rows)
    );
    std::fs::create_dir_all("bench_results").ok();
    std::fs::write("bench_results/enduser.json", rows_to_json(&rows).to_string_pretty())
        .expect("write bench_results/enduser.json");
    for r in &rows {
        assert!(
            r.time_max.as_secs() < 300,
            "paper claim violated: {} took {:?} (> 5 min)",
            r.label,
            r.time_max
        );
    }
    println!("\nall configurations complete in < 5 minutes — paper claim holds ✓");
}
