//! Bench T2 — paper Table 2: the performance benchmark (tSPM+ only).
//!
//! Four tSPM+ rows (memory/file × ±screening) on the Synthea-COVID-like
//! cohort (35,000 patients × ~318 entries at scale 1.0; default scale
//! 0.02 here — the full workload mines ~1.8 G sequences ≈ 28 GB which is
//! the 256 GB-class run from the paper). Also reproduces the paper's
//! 100k-patient *failure mode*: the element cap (R's 2³¹−1) is exceeded
//! and adaptive partitioning is required.
//!
//! Env overrides: `TSPM_BENCH_SCALE`, `TSPM_BENCH_ITERS`.

use tspm_plus::bench_util::{experiments, render_table, rows_to_json};

fn env_f64(key: &str, default: f64) -> f64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let scale = env_f64("TSPM_BENCH_SCALE", 0.02);
    let iters = env_usize("TSPM_BENCH_ITERS", 3);
    eprintln!("table2: scale={scale} iterations={iters} (paper: scale=1.0, 10 iters)");

    // The overflow prologue (paper: the 100k run "failed due to an error
    // ... R has a limit of (2^31)-1 entries per vector").
    let (total, cap, chunks) = experiments::table2_overflow_demo(scale);
    println!(
        "overflow gate: {total} predicted sequences vs scaled element cap {cap} \
         → adaptive partitioning resolves it with {chunks} chunks"
    );

    let rows = experiments::table2(scale, iters);
    print!("{}", render_table("Table 2 — performance benchmark (tSPM+)", &rows));
    std::fs::create_dir_all("bench_results").ok();
    std::fs::write("bench_results/table2.json", rows_to_json(&rows).to_string_pretty())
        .expect("write bench_results/table2.json");
    eprintln!("wrote bench_results/table2.json");
}
