//! Property tests over the coordinator invariants (hand-rolled
//! generators — the offline registry has no proptest): random cohorts ×
//! random configurations, asserting the invariants that must hold for
//! *every* input, not just the curated fixtures:
//!
//! * conservation — every mining path emits exactly n·(n−1)/2 records
//!   per patient (post filter), no loss, no duplication;
//! * routing — pipeline sharding processes every chunk exactly once
//!   regardless of shard count / queue depth;
//! * state — screening is idempotent and thread-count invariant;
//! * encoding — the sequence hash is injective over the vocabulary.

use tspm_plus::dbmart::{decode_seq, encode_seq, DbMart, DbMartEntry, NumericDbMart};
use tspm_plus::mining::{self, MiningConfig, SeqRecord};
use tspm_plus::pipeline::{self, PipelineConfig};
use tspm_plus::rng::Rng;
use tspm_plus::sparsity::{self, SparsityConfig};

/// Random dbmart generator: patients with random entry counts, dates and
/// codes, including adversarial shapes (empty patients, single-entry
/// patients, all-same-date, all-same-code).
fn random_dbmart(rng: &mut Rng) -> DbMart {
    let n_patients = 1 + rng.gen_range(40);
    let vocab = 1 + rng.gen_range(30);
    let horizon = 1 + rng.gen_range(1000);
    let mut entries = Vec::new();
    for p in 0..n_patients {
        let shape = rng.gen_range(5);
        let n = match shape {
            0 => 0,                            // empty patient
            1 => 1,                            // single entry
            _ => 1 + rng.gen_range(60) as usize,
        };
        for _ in 0..n {
            let date = if shape == 2 {
                42 // all-same-date patient
            } else {
                rng.gen_range(horizon) as i32
            };
            let code = if shape == 3 {
                0 // all-same-code patient
            } else {
                rng.gen_range(vocab)
            };
            entries.push(DbMartEntry {
                patient_id: format!("p{p}"),
                date,
                phenx: format!("c{code}"),
                description: None,
            });
        }
    }
    DbMart::new(entries)
}

fn sorted(mut v: Vec<SeqRecord>) -> Vec<SeqRecord> {
    v.sort_unstable_by_key(|r| (r.seq, r.pid, r.duration));
    v
}

#[test]
fn conservation_across_all_paths() {
    let mut meta = Rng::new(0xC0FFEE);
    for case in 0..25 {
        let mut rng = Rng::new(case);
        let mart = random_dbmart(&mut rng);
        let db = NumericDbMart::encode(&mart);
        let cfg = MiningConfig {
            threads: 1 + meta.gen_range(4) as usize,
            first_occurrence_only: meta.gen_bool(0.5),
            ..Default::default()
        };

        // exact expected count from the formula
        let mut per_patient: std::collections::HashMap<u32, Vec<(i32, u32)>> = Default::default();
        for e in &db.entries {
            per_patient.entry(e.patient).or_default().push((e.date, e.phenx));
        }
        let mut expect = 0u64;
        for rows in per_patient.values() {
            let n = if cfg.first_occurrence_only {
                let mut codes: Vec<u32> = rows.iter().map(|&(_, c)| c).collect();
                codes.sort_unstable();
                codes.dedup();
                codes.len() as u64
            } else {
                rows.len() as u64
            };
            expect += n * n.saturating_sub(1) / 2;
        }

        let batch = mining::mine_sequences(&db, &cfg).unwrap();
        assert_eq!(batch.len() as u64, expect, "case={case} batch count");

        // pipeline must agree record-for-record
        let streamed = pipeline::run(
            &db,
            &PipelineConfig {
                mining: cfg.clone(),
                chunk_cap: 2_000 + meta.gen_range(100_000),
                queue_depth: 1 + meta.gen_range(4) as usize,
                shards: 1 + meta.gen_range(5) as usize,
                screen: None,
                spill_dir: None,
            },
        );
        match streamed {
            Ok(s) => assert_eq!(
                sorted(batch.records.clone()),
                sorted(s.sequences.materialize().unwrap().records),
                "case={case} pipeline mismatch"
            ),
            Err(e) => {
                // only legal failure: one patient exceeds the random cap
                assert!(e.to_string().contains("alone yields"), "case={case}: {e}");
            }
        }
    }
}

#[test]
fn screening_idempotent_and_thread_invariant() {
    let mut meta = Rng::new(77);
    for case in 0..20 {
        let mut rng = Rng::new(1000 + case);
        let mart = random_dbmart(&mut rng);
        let db = NumericDbMart::encode(&mart);
        let mined = mining::mine_sequences(&db, &MiningConfig::default()).unwrap();
        let threshold = 1 + meta.gen_range(6) as u32;

        let mut once = mined.records.clone();
        let s1 = sparsity::screen(&mut once, &SparsityConfig { min_patients: threshold, threads: 1 });
        // idempotence
        let mut twice = once.clone();
        let s2 = sparsity::screen(&mut twice, &SparsityConfig { min_patients: threshold, threads: 1 });
        assert_eq!(once, twice, "case={case} screen not idempotent");
        assert_eq!(s1.records_after, s2.records_before);
        assert_eq!(s2.records_before, s2.records_after);
        // thread invariance
        for threads in [2usize, 4] {
            let mut t = mined.records.clone();
            sparsity::screen(&mut t, &SparsityConfig { min_patients: threshold, threads });
            assert_eq!(sorted(once.clone()), sorted(t), "case={case} threads={threads}");
        }
        // survivor property: every surviving sequence occurs in >= threshold
        // distinct patients, verified independently
        let mut by_seq: std::collections::HashMap<u64, std::collections::BTreeSet<u32>> =
            Default::default();
        for r in &mined.records {
            by_seq.entry(r.seq).or_default().insert(r.pid);
        }
        for r in &once {
            assert!(by_seq[&r.seq].len() as u32 >= threshold, "case={case}");
        }
        // completeness: no qualifying record was dropped
        let expect: u64 = mined
            .records
            .iter()
            .filter(|r| by_seq[&r.seq].len() as u32 >= threshold)
            .count() as u64;
        assert_eq!(s1.records_after, expect, "case={case}");
    }
}

#[test]
fn sequence_hash_injective_and_monotone() {
    let mut rng = Rng::new(5);
    let mut seen = std::collections::HashMap::new();
    for _ in 0..50_000 {
        let s = rng.gen_range(10_000_000) as u32;
        let e = rng.gen_range(10_000_000) as u32;
        let h = encode_seq(s, e);
        assert_eq!(decode_seq(h), (s, e));
        if let Some(prev) = seen.insert(h, (s, e)) {
            assert_eq!(prev, (s, e), "hash collision");
        }
    }
    // monotone in (start, end) lexicographic order
    assert!(encode_seq(3, 9_999_999) < encode_seq(4, 0));
}

#[test]
fn durations_always_consistent_with_dates() {
    // For every mined record, the duration must equal the date delta of
    // *some* admissible pair of the patient's entries with those codes.
    let mut rng = Rng::new(31);
    for case in 0..10 {
        let mart = random_dbmart(&mut Rng::new(900 + case));
        let db = NumericDbMart::encode(&mart);
        let mined = mining::mine_sequences(&db, &MiningConfig::default()).unwrap();
        let mut per_patient: std::collections::HashMap<u32, Vec<(i32, u32)>> = Default::default();
        for e in &db.entries {
            per_patient.entry(e.patient).or_default().push((e.date, e.phenx));
        }
        // probe a sample (full check is O(n·m))
        for _ in 0..200.min(mined.len()) {
            let r = mined.records[rng.gen_range(mined.len() as u64) as usize];
            let (s, e) = decode_seq(r.seq);
            let rows = &per_patient[&r.pid];
            let ok = rows.iter().any(|&(d1, c1)| {
                c1 == s
                    && rows.iter().any(|&(d2, c2)| {
                        c2 == e && d2 >= d1 && (d2 - d1) as u32 == r.duration
                    })
            });
            assert!(ok, "case={case}: record {r:?} has no supporting entry pair");
        }
    }
}

#[test]
fn pipeline_backpressure_never_deadlocks_or_drops() {
    // Adversarial queue/shard combinations, including shards >> chunks
    // and queue_depth 1.
    let mart = random_dbmart(&mut Rng::new(4242));
    let db = NumericDbMart::encode(&mart);
    let batch = mining::mine_sequences(&db, &MiningConfig::default()).unwrap();
    for (shards, depth) in [(1usize, 1usize), (8, 1), (2, 2), (16, 3), (3, 16)] {
        let result = pipeline::run(
            &db,
            &PipelineConfig {
                chunk_cap: 1_000_000,
                queue_depth: depth,
                shards,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(
            sorted(batch.records.clone()),
            sorted(result.sequences.materialize().unwrap().records),
            "shards={shards} depth={depth}"
        );
    }
}

/// seqstore round-trip — write N random `SeqRecord`s, read them back,
/// assert bit-identical (order and every field preserved), across sizes
/// from empty to well past the writer's buffer, via both the bulk and
/// the streaming reader. Guards the engine's file-backed backend.
#[test]
fn seqstore_roundtrip_is_bit_identical() {
    use tspm_plus::seqstore;
    let dir = std::env::temp_dir().join("tspm_prop_seqstore");
    std::fs::create_dir_all(&dir).unwrap();
    let mut meta = Rng::new(20231107);
    for case in 0..12 {
        let n = match case {
            0 => 0usize,
            1 => 1,
            // past WRITER_BUFFER_BYTES (1 MiB = 65_536 records)
            2 => 70_000,
            _ => 1 + meta.gen_range(20_000) as usize,
        };
        let mut r = Rng::new(case as u64);
        let records: Vec<SeqRecord> = (0..n)
            .map(|_| SeqRecord {
                // full u64 range, incl. values with high bytes set
                seq: r.next_u64(),
                pid: r.next_u32(),
                duration: r.next_u32(),
            })
            .collect();
        let path = dir.join(format!("case_{case}.tspm"));
        seqstore::write_file(&path, &records).unwrap();

        let bulk = seqstore::read_file(&path).unwrap();
        assert_eq!(bulk, records, "case={case} bulk read diverged");

        let reader = seqstore::SeqReader::open(&path).unwrap();
        assert_eq!(reader.remaining(), n as u64, "case={case} header count");
        let streamed: Vec<SeqRecord> = reader.map(|x| x.unwrap()).collect();
        assert_eq!(streamed, records, "case={case} streaming read diverged");
        std::fs::remove_file(&path).unwrap();
    }
}

/// Shard-merge determinism: on random cohorts (including the adversarial
/// shapes `random_dbmart` mixes in), sharded mining with 1, 2 and 8
/// shards — under 1, 2 and 4 workers — yields exactly the batch-path
/// sorted output. The merge happens in stable shard order, so neither
/// the shard layout nor the scheduling may change the multiset.
#[test]
fn sharded_merge_deterministic_on_random_dbmarts() {
    let mut meta = Rng::new(0x5AD5);
    for case in 0..10 {
        let mart = random_dbmart(&mut Rng::new(3000 + case));
        let db = NumericDbMart::encode(&mart);
        let first_only = meta.gen_bool(0.5);
        let base = MiningConfig { first_occurrence_only: first_only, ..Default::default() };
        let golden = sorted(mining::mine_sequences(&db, &base).unwrap().records);
        for shards in [1usize, 2, 8] {
            for threads in [1usize, 2, 4] {
                let cfg = MiningConfig { shards, threads, ..base.clone() };
                let got = sorted(mining::mine_sequences_sharded(&db, &cfg).unwrap().records);
                assert_eq!(
                    got, golden,
                    "case={case} shards={shards} threads={threads} first_only={first_only}"
                );
            }
        }
    }
}

/// TargetSpec canonicalization: spec equality must be insensitive to the
/// order and multiplicity of the code list. Any permutation with any
/// duplication canonicalizes to the same spec, the same rendering, the
/// same JSON — and, end to end, the same targeted mining bytes.
#[test]
fn target_spec_canonicalization_order_and_duplicate_insensitive() {
    use tspm_plus::engine::Engine;
    use tspm_plus::target::{TargetPos, TargetSpec};
    let mut rng = Rng::new(0x7A96);
    for case in 0..40u64 {
        let n = 1 + rng.gen_range(12) as usize;
        let codes: Vec<u32> = (0..n).map(|_| rng.gen_range(30) as u32).collect();
        // A shuffled view of the same set, with random extra duplicates.
        let mut noisy = codes.clone();
        for _ in 0..rng.gen_range(8) {
            noisy.push(codes[rng.gen_range(n as u64) as usize]);
        }
        for i in (1..noisy.len()).rev() {
            let j = rng.gen_range((i + 1) as u64) as usize;
            noisy.swap(i, j);
        }
        let pos = match rng.gen_range(3) {
            0 => TargetPos::First,
            1 => TargetPos::Second,
            _ => TargetPos::Either,
        };
        let lo = if rng.gen_bool(0.5) { Some(rng.gen_range(100) as u32) } else { None };
        let hi = if rng.gen_bool(0.5) {
            Some(lo.unwrap_or(0) + rng.gen_range(500) as u32)
        } else {
            None
        };
        let a = TargetSpec::for_codes(codes.clone()).with_pos(pos).with_duration_band(lo, hi);
        let b = TargetSpec::for_codes(noisy).with_pos(pos).with_duration_band(lo, hi);
        assert_eq!(a, b, "case={case}: canonical specs must be equal");
        assert_eq!(a.render(), b.render(), "case={case}");
        assert_eq!(
            a.to_json().to_string_compact(),
            b.to_json().to_string_compact(),
            "case={case}"
        );
        // The canonical code list is strictly sorted (sorted + deduped).
        let cs = a.codes().expect("non-empty code list");
        assert!(cs.windows(2).all(|w| w[0] < w[1]), "case={case}: {cs:?}");

        // End to end on a small cohort: both spellings mine to identical
        // bytes (a handful of cases keeps the runtime bounded).
        if case < 4 {
            let mart = random_dbmart(&mut Rng::new(7000 + case));
            let db = NumericDbMart::encode(&mart);
            let vocab = db.num_phenx() as u32;
            let work = std::env::temp_dir().join(format!("tspm_prop_target_{case}"));
            let cfg = MiningConfig { work_dir: work, ..Default::default() };
            let clamp = |s: &TargetSpec| {
                // keep codes inside this cohort's vocabulary
                let kept: Vec<u32> =
                    s.codes().unwrap().iter().copied().filter(|&c| c < vocab).collect();
                if kept.is_empty() {
                    TargetSpec::all().with_duration_band(lo, hi)
                } else {
                    TargetSpec::for_codes(kept).with_pos(pos).with_duration_band(lo, hi)
                }
            };
            let run = |spec: TargetSpec| {
                let out = Engine::from_dbmart(db.clone())
                    .mine(cfg.clone())
                    .target(spec)
                    .run()
                    .unwrap();
                sorted(out.sequences.materialize().unwrap().records)
            };
            assert_eq!(run(clamp(&a)), run(clamp(&b)), "case={case}: mined bytes diverged");
        }
    }
}

/// The engine façade is a pure re-orchestration: on every random cohort
/// and every backend it yields exactly the expert-layer mine+screen
/// result.
#[test]
fn engine_backends_match_expert_layer_on_random_cohorts() {
    use tspm_plus::engine::{BackendChoice, Engine};
    let mut meta = Rng::new(99);
    for case in 0..6 {
        let mart = random_dbmart(&mut Rng::new(1000 + case));
        let db = NumericDbMart::encode(&mart);
        let sc = SparsityConfig { min_patients: 1 + meta.gen_range(4) as u32, threads: 2 };
        let work_dir = std::env::temp_dir().join(format!("tspm_prop_engine_{case}"));
        let cfg = MiningConfig { work_dir, ..Default::default() };

        let mut expert = mining::mine_sequences(&db, &cfg).unwrap().records;
        sparsity::screen(&mut expert, &sc);
        let expert = sorted(expert);

        for backend in [
            BackendChoice::Auto,
            BackendChoice::Sharded,
            BackendChoice::FileBacked,
            BackendChoice::Streaming,
        ] {
            let out = Engine::from_dbmart(db.clone())
                .mine(cfg.clone())
                .screen(sc)
                .backend(backend)
                .memory_budget(1 << 20)
                .run()
                .unwrap();
            assert_eq!(
                sorted(out.sequences.materialize().unwrap().records),
                expert,
                "case={case} backend={backend:?}"
            );
        }
    }
}
