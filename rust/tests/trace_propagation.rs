//! End-to-end trace propagation across the serving wire.
//!
//! A client stamps its requests with a `trace_id` (the optional
//! envelope key documented in `serve::protocol`); the server must adopt
//! that id as the trace of its own `serve.request` root span and of
//! every span hanging off it — the retroactive `serve.admission`
//! measurement, the `serve.route` registry hop, and the query layer's
//! `query.block_scan` leaves. An unstamped connection must instead get
//! server-generated ids. Both are asserted by parsing the JSONL the
//! server's tracer writes into a [`MemorySink`].

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use tspm_plus::json::Json;
use tspm_plus::mining::SeqRecord;
use tspm_plus::obs::{MemorySink, TraceId, Tracer};
use tspm_plus::query::{self, IndexConfig};
use tspm_plus::rng::Rng;
use tspm_plus::seqstore::{self, SeqFileSet};
use tspm_plus::serve::{Client, Registry, ServeConfig, Server};

/// Small blocks so the fixture spans several and a cold `by_sequence`
/// really performs block scans.
const BLOCK_RECORDS: usize = 32;

fn tmpdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("tspm_trace_prop_{}", std::process::id()))
        .join(name);
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Spill a random sorted multiset and build a pid-indexed artifact.
fn build_artifact(name: &str) -> (PathBuf, Vec<SeqRecord>) {
    let mut r = Rng::new(41);
    let mut records: Vec<SeqRecord> = (0..2_000)
        .map(|_| SeqRecord {
            seq: r.gen_range(24),
            pid: r.gen_range(32) as u32,
            duration: r.gen_range(350) as u32,
        })
        .collect();
    records.sort_unstable_by_key(|x| (x.seq, x.pid, x.duration));
    let dir = tmpdir(name);
    let spill = dir.join("part_0.tspm");
    seqstore::write_file(&spill, &records).unwrap();
    let input = SeqFileSet {
        files: vec![spill],
        total_records: records.len() as u64,
        num_patients: 32,
        num_phenx: 0,
    };
    let out = dir.join("idx");
    query::index::build(
        &input,
        &out,
        &IndexConfig { block_records: BLOCK_RECORDS, ..Default::default() },
        None,
    )
    .unwrap();
    (out, records)
}

fn span_name(v: &Json) -> &str {
    v.get("name").and_then(Json::as_str).unwrap_or("")
}

fn span_trace(v: &Json) -> &str {
    v.get("trace").and_then(Json::as_str).unwrap_or("")
}

#[test]
fn client_trace_id_propagates_into_server_spans() {
    let (dir, records) = build_artifact("propagation");
    let registry = Arc::new(Registry::new(1 << 20));
    registry.open_and_register("idx", &dir).unwrap();

    let sink = Arc::new(MemorySink::new());
    let cfg = ServeConfig {
        tracer: Some(Tracer::new(sink.clone())),
        poll_interval: Duration::from_millis(5),
        idle_timeout: Duration::from_secs(10),
        ..ServeConfig::default()
    };
    let server = Server::bind("127.0.0.1:0", registry, cfg).unwrap();
    let addr = server.local_addr().to_string();
    let (handle, join) = server.spawn();

    // A short client-chosen id: from_hex accepts 1–32 hex chars, the
    // wire carries it verbatim, the server re-renders it zero-padded.
    let want = TraceId::from_hex("c0ffee").unwrap();
    let want_hex = want.to_hex();

    let mut stamped = Client::connect(&addr).unwrap();
    stamped.set_trace_id(want);
    let probe = records[records.len() / 2].seq;
    let (recs, _) = stamped.by_sequence(None, probe, None).unwrap();
    assert!(!recs.is_empty(), "fixture probe must exist");
    assert_eq!(stamped.top_k(None, 5).unwrap().len(), 5);
    // The metrics frame flows through the same traced request path.
    let text = stamped.metrics().unwrap();
    assert!(text.contains("tspm_serve_requests"), "metrics frame: {text}");

    // A second connection that never stamps anything.
    let mut plain = Client::connect(&addr).unwrap();
    plain.ping().unwrap();

    drop(stamped);
    drop(plain);
    handle.shutdown();
    join.join().unwrap().expect("server drains cleanly");

    let spans: Vec<Json> =
        sink.lines().iter().map(|l| Json::parse(l).expect("span lines are JSON")).collect();
    assert!(!spans.is_empty(), "server tracer emitted nothing");

    // Every span of the stamped connection carries the client's id.
    let ours: Vec<&Json> = spans.iter().filter(|v| span_trace(v) == want_hex).collect();
    let names: Vec<&str> = ours.iter().map(|v| span_name(v)).collect();
    let count = |n: &str| names.iter().filter(|x| **x == n).count();
    assert_eq!(count("serve.request"), 3, "one root per stamped request: {names:?}");
    assert_eq!(count("serve.admission"), 1, "admission attaches once per connection");
    assert_eq!(count("serve.route"), 2, "by_sequence and top_k route; metrics does not");
    assert!(count("query.block_scan") >= 1, "cold by_sequence must scan blocks: {names:?}");

    // Child spans link to a stamped serve.request root by parent id.
    let root_ids: Vec<u64> = ours
        .iter()
        .filter(|v| span_name(v) == "serve.request")
        .map(|v| v.get("span").and_then(Json::as_u64).expect("span id"))
        .collect();
    for v in ours.iter().filter(|v| span_name(v) != "serve.request") {
        let parent = v.get("parent").and_then(Json::as_u64);
        assert!(
            parent.is_some_and(|p| root_ids.contains(&p)),
            "{} span must hang off a serve.request root: {v:?}",
            span_name(v)
        );
    }

    // The request roots record the wire kind as an attribute.
    let kinds: Vec<&str> = ours
        .iter()
        .filter(|v| span_name(v) == "serve.request")
        .map(|v| {
            v.get("attrs").and_then(|a| a.get("kind")).and_then(Json::as_str).expect("kind attr")
        })
        .collect();
    for k in ["by_sequence", "top_k", "metrics"] {
        assert!(kinds.contains(&k), "missing request kind {k}: {kinds:?}");
    }

    // The unstamped connection still gets traced — under a fresh
    // server-generated id, never the zero id, never the client's.
    let plain_roots: Vec<&Json> = spans
        .iter()
        .filter(|v| {
            span_name(v) == "serve.request"
                && v.get("attrs").and_then(|a| a.get("kind")).and_then(Json::as_str)
                    == Some("ping")
        })
        .collect();
    assert_eq!(plain_roots.len(), 1, "exactly one ping request");
    let generated = span_trace(plain_roots[0]);
    assert_eq!(generated.len(), 32, "ids render as 32 hex chars: {generated}");
    assert_ne!(generated, want_hex);
    assert_ne!(generated, TraceId::NONE.to_hex(), "generated ids are never zero");
}
