//! Ingest conformance harness — merged views and compaction.
//!
//! The ingest layer promises that segmenting a cohort is *invisible*:
//! under the pid-partition contract of `tspm_plus::ingest`, the full
//! query surface over a [`MergedView`] is **byte-identical** to a
//! [`QueryService`] over one artifact built from the union cohort, and
//! a compacted segment set is **bit-identical** on disk to a fresh
//! full-cohort index. Segment splits are exactly the kind of hidden
//! axis that slips past happy-path tests (a merge that works for two
//! segments can still tie-break wrong for five), so this harness reuses
//! the adversarial cohort shapes of `conformance.rs` — empty cohorts,
//! single-entry patients, heavy skew, duplicate timestamps, maximal
//! durations, randomized mixtures — and drives every one through every
//! split into 1/2/5 segments by random pid partition, across block
//! sizes 7/128/4096 and with caching on and off.
//!
//! Compaction gets property tests on top: bit-identical output across
//! memory budgets (1 KiB / 64 KiB / unbounded), idempotence
//! (`compact(compact(S))` changes nothing), equality with a fresh
//! `tspm index` of the union, and crash safety (an injected
//! mid-compaction failure leaves the old manifest live, answering, and
//! free of partial artifacts).

use std::path::{Path, PathBuf};
use tspm_plus::dbmart::{DbMart, DbMartEntry, NumericDbMart};
use tspm_plus::ingest::{compact, CompactConfig, MergedView, SegmentSet};
use tspm_plus::mining::{self, MiningConfig, SeqRecord};
use tspm_plus::query::{index, IndexConfig, QueryService, QuerySurface, SeqIndex};
use tspm_plus::rng::Rng;
use tspm_plus::seqstore::{self, SeqFileSet};

const BLOCK_SIZES: [usize; 3] = [7, 128, 4096];
const SPLITS: [usize; 3] = [1, 2, 5];
const CACHES: [usize; 2] = [0, 1 << 20];

fn entry(p: &str, date: i32, x: &str) -> DbMartEntry {
    DbMartEntry { patient_id: p.into(), date, phenx: x.into(), description: None }
}

fn sorted(mut v: Vec<SeqRecord>) -> Vec<SeqRecord> {
    v.sort_unstable_by_key(|r| (r.seq, r.pid, r.duration));
    v
}

/// Serialize sorted records to their canonical little-endian byte layout
/// so "byte-identical" is literal, not just field-wise equality.
fn record_bytes(records: &[SeqRecord]) -> Vec<u8> {
    let mut out = Vec::with_capacity(records.len() * 16);
    for r in records {
        out.extend_from_slice(&r.seq.to_le_bytes());
        out.extend_from_slice(&r.pid.to_le_bytes());
        out.extend_from_slice(&r.duration.to_le_bytes());
    }
    out
}

/// Unique work directory per (shape, axis point) so concurrently running
/// tests never share file names.
fn work_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("tspm_ing_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Mine the cohort in-memory and return the golden sorted records plus
/// the global (num_patients, num_phenx) the whole harness pins. Ingest
/// segments are screened at `min_patients = 1` (sort-only), so the
/// golden run is the mined output itself, sorted into spill order.
fn golden_of(mart: &DbMart, cfg: &MiningConfig) -> (Vec<SeqRecord>, u32, u32) {
    let db = NumericDbMart::encode(mart);
    let records = sorted(mining::mine_sequences(&db, cfg).unwrap().records);
    (records, db.num_patients() as u32, db.lookup.phenx.len() as u32)
}

/// Write `records` (already in spill order) as a single-file run that
/// carries the *global* cohort dimensions — the pid-partition contract:
/// every segment indexes the same dense pid space.
fn run_file(dir: &Path, records: &[SeqRecord], np: u32, nx: u32) -> SeqFileSet {
    std::fs::create_dir_all(dir).unwrap();
    let path = dir.join("run.tspm");
    seqstore::write_file(&path, records).unwrap();
    SeqFileSet {
        files: vec![path],
        total_records: records.len() as u64,
        num_patients: np,
        num_phenx: nx,
    }
}

/// One artifact over the whole cohort — the reference every merged view
/// must match byte for byte.
fn build_full(dir: &Path, records: &[SeqRecord], np: u32, nx: u32, block: usize) -> SeqIndex {
    let input = run_file(dir, records, np, nx);
    index::build(
        &input,
        &dir.join("idx"),
        &IndexConfig { block_records: block, ..Default::default() },
        None,
    )
    .unwrap()
}

/// Partition patients into `parts` groups by a seeded coin and build one
/// segment per group (empty groups included — an empty segment is a
/// legal, adversarial member of a set).
#[allow(clippy::too_many_arguments)]
fn build_split_set(
    set_dir: &Path,
    input_dir: &Path,
    records: &[SeqRecord],
    np: u32,
    nx: u32,
    block: usize,
    parts: usize,
    seed: u64,
) -> SegmentSet {
    let mut rng = Rng::new(seed);
    let group_of: Vec<usize> =
        (0..np).map(|_| rng.gen_range(parts as u64) as usize).collect();
    let mut set = SegmentSet::init(set_dir).unwrap();
    for g in 0..parts {
        let part: Vec<SeqRecord> = records
            .iter()
            .copied()
            .filter(|r| group_of[r.pid as usize] == g)
            .collect();
        let input = run_file(&input_dir.join(format!("part{g}")), &part, np, nx);
        set.add_segment(&input, &IndexConfig { block_records: block, ..Default::default() }, None)
            .unwrap();
    }
    set
}

/// The whole query surface, compared answer by answer. `ctx` names the
/// axis point so a failure says exactly which split broke.
fn assert_surfaces_identical(
    ctx: &str,
    full: &dyn QuerySurface,
    view: &dyn QuerySurface,
    seqs: &[u64],
    np: u32,
) {
    assert_eq!(view.describe(), full.describe(), "{ctx}: describe");

    let mut probe_seqs = seqs.to_vec();
    probe_seqs.push(u64::MAX); // absent sequence
    for &s in &probe_seqs {
        assert_eq!(
            record_bytes(&view.by_sequence(s).unwrap()),
            record_bytes(&full.by_sequence(s).unwrap()),
            "{ctx}: by_sequence({s})"
        );
        for (lo, hi) in [(0, u32::MAX), (0, 0), (1, 1000)] {
            assert_eq!(
                *view.patients_with(s, lo, hi).unwrap(),
                *full.patients_with(s, lo, hi).unwrap(),
                "{ctx}: patients_with({s}, {lo}, {hi})"
            );
        }
        for buckets in [1usize, 3, 7] {
            assert_eq!(
                *view.duration_histogram(s, buckets).unwrap(),
                *full.duration_histogram(s, buckets).unwrap(),
                "{ctx}: histogram({s}, {buckets})"
            );
        }
        assert!(view.duration_histogram(s, 0).is_err(), "{ctx}: 0 buckets must fail");
    }

    // Every patient plus two past the dense space (must answer empty,
    // identically, not panic).
    for pid in 0..np + 2 {
        let full_run = record_bytes(&full.by_patient(pid).unwrap());
        assert_eq!(
            record_bytes(&view.by_patient(pid).unwrap()),
            full_run,
            "{ctx}: by_patient({pid})"
        );
        let mut streamed = Vec::new();
        let total = view
            .visit_patient(pid, &mut |chunk| {
                streamed.extend_from_slice(chunk);
                Ok(())
            })
            .unwrap();
        assert_eq!(record_bytes(&streamed), full_run, "{ctx}: visit_patient({pid})");
        assert_eq!(total as usize, streamed.len(), "{ctx}: visit_patient({pid}) total");
    }

    for k in [0usize, 1, 3, seqs.len() + 7] {
        assert_eq!(
            *view.top_k_by_support(k).unwrap(),
            *full.top_k_by_support(k).unwrap(),
            "{ctx}: top_k({k})"
        );
    }
}

/// Harness core: for a cohort shape, sweep block size × split count ×
/// cache setting and assert the merged view matches the single-artifact
/// reference on the full surface.
fn assert_ingest_conforms(shape: &str, mart: &DbMart, cfg: &MiningConfig) {
    let (golden, np, nx) = golden_of(mart, cfg);
    let base = work_dir(shape);
    for block in BLOCK_SIZES {
        let full_dir = base.join(format!("full_b{block}"));
        let full_idx = build_full(&full_dir, &golden, np, nx, block);
        let seqs: Vec<u64> = full_idx.seqs.iter().map(|e| e.seq).collect();
        for parts in SPLITS {
            let set_dir = base.join(format!("set_b{block}_k{parts}"));
            let input_dir = base.join(format!("in_b{block}_k{parts}"));
            build_split_set(
                &set_dir,
                &input_dir,
                &golden,
                np,
                nx,
                block,
                parts,
                0xD15C0 + parts as u64,
            );
            for cache in CACHES {
                let full = QueryService::open_with_cache(&full_idx.dir, cache).unwrap();
                let view = MergedView::open(&set_dir, cache).unwrap();
                assert_eq!(view.num_segments(), parts, "{shape}: segment count");
                let ctx = format!("{shape}/b{block}/k{parts}/c{cache}");
                assert_surfaces_identical(&ctx, &full, &view, &seqs, np);
            }
        }
    }
    let _ = std::fs::remove_dir_all(&base);
}

// ---------------------------------------------------------------------------
// Adversarial shapes (mirroring conformance.rs)
// ---------------------------------------------------------------------------

#[test]
fn ingest_conformance_empty_cohort() {
    let mart = DbMart::new(vec![]);
    assert_ingest_conforms("empty", &mart, &MiningConfig::default());
}

#[test]
fn ingest_conformance_single_entry_patients() {
    let mart = DbMart::new(
        (0..40).map(|p| entry(&format!("p{p}"), p, &format!("x{}", p % 7))).collect(),
    );
    assert_ingest_conforms("single_entry", &mart, &MiningConfig::default());
}

#[test]
fn ingest_conformance_heavily_skewed() {
    let mut entries = Vec::new();
    for i in 0..200 {
        entries.push(entry("whale", i, &format!("x{}", i % 23)));
    }
    let mut rng = Rng::new(42);
    for p in 0..50 {
        for i in 0..(1 + rng.gen_range(3)) {
            entries.push(entry(
                &format!("minnow{p}"),
                i as i32,
                &format!("x{}", rng.gen_range(23)),
            ));
        }
    }
    let mart = DbMart::new(entries);
    assert_ingest_conforms("skewed", &mart, &MiningConfig::default());
}

#[test]
fn ingest_conformance_duplicate_timestamps() {
    let mut entries = Vec::new();
    for p in 0..20 {
        for i in 0..10 {
            entries.push(entry(&format!("p{p}"), 1000 + p, &format!("c{}", i % 4)));
        }
    }
    let mart = DbMart::new(entries);
    assert_ingest_conforms("dup_ts", &mart, &MiningConfig::default());
}

#[test]
fn ingest_conformance_max_duration_buckets() {
    let mut entries = Vec::new();
    for p in 0..8 {
        let pid = format!("p{p}");
        entries.push(entry(&pid, 0, "start"));
        entries.push(entry(&pid, 2_100_000_000, "end"));
        entries.push(entry(&pid, 1_000_000_000 + p, "mid"));
    }
    let mart = DbMart::new(entries);
    assert_ingest_conforms("max_dur", &mart, &MiningConfig::default());
    assert_ingest_conforms(
        "max_dur_monthly",
        &mart,
        &MiningConfig { duration_unit_days: 30, ..Default::default() },
    );
}

#[test]
fn ingest_conformance_random_mixture() {
    for seed in 0..3u64 {
        let mut rng = Rng::new(0xBEEF + seed);
        let mut entries = Vec::new();
        let n_patients = 1 + rng.gen_range(30);
        for p in 0..n_patients {
            let n = match rng.gen_range(4) {
                0 => 1,
                1 => 2,
                _ => 1 + rng.gen_range(40),
            };
            let same_date = rng.gen_range(3) == 0;
            for _ in 0..n {
                let date = if same_date { 7 } else { rng.gen_range(3000) as i32 };
                entries.push(entry(
                    &format!("p{p}"),
                    date,
                    &format!("c{}", rng.gen_range(15)),
                ));
            }
        }
        let mart = DbMart::new(entries);
        assert_ingest_conforms(
            &format!("random{seed}"),
            &mart,
            &MiningConfig { include_self_pairs: false, ..Default::default() },
        );
    }
}

// ---------------------------------------------------------------------------
// Compaction properties
// ---------------------------------------------------------------------------

/// Every file of an artifact directory, name-sorted, for bit-identity
/// comparison.
fn artifact_files(dir: &Path) -> Vec<(String, Vec<u8>)> {
    let mut out: Vec<(String, Vec<u8>)> = std::fs::read_dir(dir)
        .unwrap()
        .map(|e| {
            let e = e.unwrap();
            (e.file_name().to_string_lossy().into_owned(), std::fs::read(e.path()).unwrap())
        })
        .collect();
    out.sort();
    out
}

fn assert_artifacts_bit_identical(ctx: &str, got: &Path, want: &Path) {
    let got = artifact_files(got);
    let want = artifact_files(want);
    let names = |v: &[(String, Vec<u8>)]| {
        v.iter().map(|(n, _)| n.clone()).collect::<Vec<String>>()
    };
    assert_eq!(names(&got), names(&want), "{ctx}: artifact file lists differ");
    for ((name, g), (_, w)) in got.iter().zip(&want) {
        assert!(
            g == w,
            "{ctx}: {name} differs ({} vs {} bytes)",
            g.len(),
            w.len()
        );
    }
}

/// The mixture cohort the compaction properties run on — big enough to
/// span many blocks, screened the way ingest screens (min_patients = 1).
fn compaction_cohort() -> (Vec<SeqRecord>, u32, u32) {
    let mut rng = Rng::new(0xBEEF);
    let mut entries = Vec::new();
    let n_patients = 1 + rng.gen_range(30);
    for p in 0..n_patients {
        let n = 1 + rng.gen_range(40);
        for _ in 0..n {
            entries.push(entry(
                &format!("p{p}"),
                rng.gen_range(3000) as i32,
                &format!("c{}", rng.gen_range(15)),
            ));
        }
    }
    let mart = DbMart::new(entries);
    golden_of(&mart, &MiningConfig { include_self_pairs: false, ..Default::default() })
}

/// Budget invariance + idempotence + fresh-build equality, all against
/// the same reference artifact.
#[test]
fn compaction_is_budget_invariant_idempotent_and_equals_a_fresh_build() {
    let (golden, np, nx) = compaction_cohort();
    let base = work_dir("compact_props");
    let block = 128;
    let fresh = build_full(&base.join("fresh"), &golden, np, nx, block);

    let mut first_compacted: Option<PathBuf> = None;
    for (tag, budget) in [("1k", 1024usize), ("64k", 64 << 10), ("max", usize::MAX)] {
        let set_dir = base.join(format!("set_{tag}"));
        build_split_set(
            &set_dir,
            &base.join(format!("in_{tag}")),
            &golden,
            np,
            nx,
            block,
            3,
            0xC0FFEE,
        );
        let mut set = SegmentSet::open(&set_dir).unwrap();
        let cfg = CompactConfig {
            block_records: block,
            buffer_bytes: budget,
            ..Default::default()
        };
        let idx = compact(&mut set, &cfg, None).unwrap();
        assert_eq!(set.segments().len(), 1, "budget {tag}: one live segment");
        assert_artifacts_bit_identical(
            &format!("budget {tag} vs fresh build"),
            &idx.dir,
            &fresh.dir,
        );
        // Retired segment directories are gone; no staging debris.
        assert!(!set_dir.join("compact_tmp").exists(), "budget {tag}: staging dir");
        for g in 0..3 {
            assert!(!set_dir.join(format!("seg_{g:04}")).exists(), "budget {tag}: retired");
        }
        first_compacted.get_or_insert(set_dir);
    }

    // Idempotence: compacting the already-compacted set changes nothing
    // but the segment name.
    let set_dir = first_compacted.unwrap();
    let mut set = SegmentSet::open(&set_dir).unwrap();
    let cfg = CompactConfig { block_records: block, buffer_bytes: 1024, ..Default::default() };
    let idx2 = compact(&mut set, &cfg, None).unwrap();
    assert_artifacts_bit_identical("compact(compact(S))", &idx2.dir, &fresh.dir);

    // And the compacted set still answers like the reference service.
    let full = QueryService::open_with_cache(&fresh.dir, 0).unwrap();
    let view = MergedView::open(&set_dir, 0).unwrap();
    let seqs: Vec<u64> = fresh.seqs.iter().map(|e| e.seq).collect();
    assert_surfaces_identical("compacted set", &full, &view, &seqs, np);
    let _ = std::fs::remove_dir_all(&base);
}

/// Crash safety: an injected failure mid-merge must leave the old
/// manifest byte-identical, the old segments fully answering, and no
/// partial artifact or staging directory visible.
#[test]
fn failed_compaction_leaves_the_live_set_intact() {
    let (golden, np, nx) = compaction_cohort();
    let base = work_dir("compact_crash");
    let set_dir = base.join("set");
    build_split_set(&set_dir, &base.join("in"), &golden, np, nx, 128, 2, 0xBAD5EED);

    let manifest_path = set_dir.join("segments.json");
    let manifest_before = std::fs::read(&manifest_path).unwrap();
    let listing = |dir: &Path| {
        let mut v: Vec<String> = std::fs::read_dir(dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .collect();
        v.sort();
        v
    };
    let listing_before = listing(&set_dir);
    let answers_before = record_bytes(
        &MergedView::open(&set_dir, 0).unwrap().by_sequence(golden[0].seq).unwrap(),
    );

    let mut set = SegmentSet::open(&set_dir).unwrap();
    let cfg = CompactConfig {
        block_records: 128,
        buffer_bytes: 1024,
        fail_after_records: Some(5),
    };
    let err = compact(&mut set, &cfg, None).unwrap_err();
    assert!(err.to_string().contains("injected"), "unexpected error: {err}");

    assert_eq!(
        std::fs::read(&manifest_path).unwrap(),
        manifest_before,
        "manifest bytes must be untouched by a failed compaction"
    );
    assert_eq!(
        listing(&set_dir),
        listing_before,
        "no partial artifact or staging debris may be visible"
    );
    let reopened = SegmentSet::open(&set_dir).unwrap();
    assert_eq!(reopened.segments(), ["seg_0000", "seg_0001"]);
    let view = MergedView::open(&set_dir, 0).unwrap();
    assert_eq!(
        record_bytes(&view.by_sequence(golden[0].seq).unwrap()),
        answers_before,
        "the old set must keep answering after a failed compaction"
    );

    // A plain file squatting on the staging name is an error (it is not
    // recognizable compaction debris), and it too must leave the
    // manifest alone.
    let tmp = set_dir.join("compact_tmp");
    std::fs::write(&tmp, b"not a directory").unwrap();
    let mut set = SegmentSet::open(&set_dir).unwrap();
    assert!(compact(&mut set, &CompactConfig::default(), None).is_err());
    assert!(tmp.is_file(), "an unrecognized staging path must not be deleted");
    assert_eq!(std::fs::read(&manifest_path).unwrap(), manifest_before);

    // A stale staging *directory* (debris of an interrupted run) is
    // reclaimed and compaction goes through.
    std::fs::remove_file(&tmp).unwrap();
    std::fs::create_dir(&tmp).unwrap();
    std::fs::write(tmp.join("junk.bin"), b"stale").unwrap();
    let mut set = SegmentSet::open(&set_dir).unwrap();
    let idx = compact(&mut set, &CompactConfig::default(), None).unwrap();
    assert!(!tmp.exists());
    assert_eq!(
        record_bytes(&QueryService::open_with_cache(&idx.dir, 0)
            .unwrap()
            .by_sequence(golden[0].seq)
            .unwrap()),
        answers_before
    );
    let _ = std::fs::remove_dir_all(&base);
}

// ---------------------------------------------------------------------------
// Cross-segment top-k tie-breaking
// ---------------------------------------------------------------------------

/// Regression: supports are summed across segments *before* ranking,
/// and ties rank by seq ascending — for every segment layout, including
/// layouts where per-segment supports disagree about the order.
#[test]
fn cross_segment_top_k_ties_use_the_documented_total_order() {
    // seq 5 → patients {0..5} (support 5); seqs 7 and 9 → support 4
    // each, over *different* patients so per-segment counts diverge.
    let mut records = Vec::new();
    for pid in 0..5u32 {
        records.push(SeqRecord { seq: 5, pid, duration: pid });
    }
    for pid in 0..4u32 {
        records.push(SeqRecord { seq: 7, pid, duration: 10 + pid });
    }
    for pid in 1..5u32 {
        records.push(SeqRecord { seq: 9, pid, duration: 20 + pid });
    }
    let records = sorted(records);
    let (np, nx) = (5u32, 3u32);

    let base = work_dir("topk_ties");
    let full_idx = build_full(&base.join("full"), &records, np, nx, 7);
    let full = QueryService::open_with_cache(&full_idx.dir, 0).unwrap();
    let want = full.top_k_by_support(10).unwrap();
    let order: Vec<u64> = want.iter().map(|s| s.seq).collect();
    assert_eq!(order, [5, 7, 9], "reference order: support desc, then seq asc");
    assert_eq!(want[1].patients, want[2].patients, "7 and 9 must tie");

    // Two very different pid layouts; in the second, segment 0 sees seq
    // 9 but no seq 7 at all, so any per-segment ranking shortcut breaks.
    for (tag, groups) in [("even", vec![vec![0u32, 1], vec![2, 3, 4]]),
        ("skewed", vec![vec![4u32], vec![0, 3], vec![1, 2]])]
    {
        let set_dir = base.join(format!("set_{tag}"));
        let mut set = SegmentSet::init(&set_dir).unwrap();
        for (g, pids) in groups.iter().enumerate() {
            let part: Vec<SeqRecord> =
                records.iter().copied().filter(|r| pids.contains(&r.pid)).collect();
            let input = run_file(&base.join(format!("in_{tag}_{g}")), &part, np, nx);
            set.add_segment(
                &input,
                &IndexConfig { block_records: 7, ..Default::default() },
                None,
            )
            .unwrap();
        }
        let view = MergedView::open(&set_dir, 0).unwrap();
        assert_eq!(
            *view.top_k_by_support(10).unwrap(),
            *want,
            "{tag}: merged top-k must match the single-artifact order"
        );
        for k in [1usize, 2, 3] {
            assert_eq!(
                *view.top_k_by_support(k).unwrap(),
                *full.top_k_by_support(k).unwrap(),
                "{tag}: truncation at k={k}"
            );
        }
    }
    let _ = std::fs::remove_dir_all(&base);
}
