//! Cross-backend conformance harness.
//!
//! The engine promises that its four execution backends (in-memory,
//! sharded, file-backed, streaming) are *interchangeable*: same plan in,
//! same sequence multiset out, whatever the scheduling, spill format,
//! thread count, or **result residency** (in-memory vs spilled).
//! Reordering bugs are exactly the class that slips past happy-path
//! tests, so this harness feeds **adversarial dbmart shapes** — empty
//! cohorts, single-entry patients, heavily skewed patients, duplicate
//! timestamps, maximal durations — through every backend (and through
//! every backend again with an explicitly spilled result) and asserts
//! **byte-identical** sorted output plus the `RunReport` invariants each
//! run must satisfy. Each shape's golden records additionally flow
//! through all four sparsity-screen implementations (`screen`,
//! `screen_paper_strategy`, `screen_naive`, `screen_spilled`), which
//! must agree on survivors byte-for-byte.
//!
//! Spilled-path coverage is unconditional: every shape runs every
//! backend a second time with `.output(OutputChoice::Spilled)`, so the
//! out-of-core mine and external-merge screen execute on every push.
//! `TSPM_MEMORY_BUDGET` (bytes) additionally overrides the per-shape
//! engine budget (clamped up to the streaming floor) — CI runs the
//! suite at a second budget point so residency/backend auto-resolution
//! is tested on more than one budget.
//!
//! Every future backend (async, caching, remote) gets wired into
//! `ALL_BACKENDS` below and inherits the whole battery.

use std::path::Path;
use tspm_plus::dbmart::{DbMart, DbMartEntry, NumericDbMart};
use tspm_plus::engine::{self, BackendChoice, BackendKind, Engine, OutputChoice, OutputKind};
use tspm_plus::mining::{self, MiningConfig, SeqRecord};
use tspm_plus::rng::Rng;
use tspm_plus::seqstore::{self, SeqFileSet};
use tspm_plus::sparsity::{self, SparsityConfig, SpillScreenConfig};

/// Every backend the engine can execute, paired with the kind the report
/// must name.
const ALL_BACKENDS: [(BackendChoice, BackendKind); 4] = [
    (BackendChoice::InMemory, BackendKind::InMemory),
    (BackendChoice::Sharded, BackendKind::Sharded),
    (BackendChoice::FileBacked, BackendKind::FileBacked),
    (BackendChoice::Streaming, BackendKind::Streaming),
];

fn entry(p: &str, date: i32, x: &str) -> DbMartEntry {
    DbMartEntry { patient_id: p.into(), date, phenx: x.into(), description: None }
}

fn sorted(mut v: Vec<SeqRecord>) -> Vec<SeqRecord> {
    v.sort_unstable_by_key(|r| (r.seq, r.pid, r.duration));
    v
}

/// Serialize sorted records to their canonical little-endian byte layout
/// so "byte-identical" is literal, not just field-wise equality.
fn record_bytes(records: &[SeqRecord]) -> Vec<u8> {
    let mut out = Vec::with_capacity(records.len() * 16);
    for r in records {
        out.extend_from_slice(&r.seq.to_le_bytes());
        out.extend_from_slice(&r.pid.to_le_bytes());
        out.extend_from_slice(&r.duration.to_le_bytes());
    }
    out
}

/// Unique spill directory per (shape, backend) so concurrently running
/// tests never share file names.
fn work_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("tspm_conf_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Budget override (bytes) so CI can re-run the suite at a second
/// budget point; clamped up to the per-shape streaming floor by the
/// caller.
fn env_budget() -> Option<u64> {
    std::env::var("TSPM_MEMORY_BUDGET").ok()?.parse().ok()
}

/// The harness core: run the identical plan through all four backends —
/// once with auto residency, once pinned to a spilled result — and
/// assert byte-identical sorted output and the `RunReport` invariants.
/// Returns the golden sorted records for shape-specific follow-up checks.
fn assert_backends_conform(shape: &str, mart: &DbMart, cfg: &MiningConfig) -> Vec<SeqRecord> {
    let db = NumericDbMart::encode(mart);
    // A budget that clears the largest single patient (streaming would
    // otherwise legitimately refuse) but sits below most totals, so the
    // streaming run really partitions (and out-of-core runs auto-spill).
    let fc = engine::forecast(&db, cfg);
    let floor = (fc.max_patient_sequences + 32) * 16;
    let budget_bytes = env_budget().unwrap_or(floor).max(floor);

    let mut golden: Option<Vec<u8>> = None;
    let mut golden_records = Vec::new();
    for (choice, kind) in ALL_BACKENDS {
        let run_cfg = MiningConfig {
            work_dir: work_dir(&format!("{shape}_{kind}")),
            ..cfg.clone()
        };
        let out = Engine::from_dbmart(db.clone())
            .mine(run_cfg)
            .backend(choice)
            .memory_budget(budget_bytes)
            .run()
            .unwrap_or_else(|e| panic!("{shape}/{kind}: {e}"));

        // --- RunReport invariants, identical for every backend ---------
        assert_eq!(out.report.backend, kind, "{shape}: report names the wrong backend");
        assert_eq!(
            out.report.output,
            out.sequences.kind(),
            "{shape}/{kind}: report names the wrong residency"
        );
        let stage_names: Vec<&str> =
            out.report.stages.iter().map(|s| s.stage.as_str()).collect();
        assert_eq!(stage_names, ["mine"], "{shape}/{kind}");
        assert_eq!(
            out.report.stages[0].records_out,
            out.sequences.len() as u64,
            "{shape}/{kind}: mine stage under/over-reports records"
        );
        assert_eq!(
            out.report.stages[0].bytes_out,
            out.sequences.byte_size(),
            "{shape}/{kind}"
        );
        assert_eq!(out.report.forecast, fc, "{shape}/{kind}: forecast drifted");
        if cfg.include_self_pairs {
            assert_eq!(
                fc.total_sequences,
                out.sequences.len() as u64,
                "{shape}/{kind}: forecast must be exact with self-pairs"
            );
        } else {
            assert!(fc.total_sequences >= out.sequences.len() as u64, "{shape}/{kind}");
        }
        assert!(
            out.report.peak_logical_bytes >= out.sequences.resident_bytes(),
            "{shape}/{kind}: peak below the resident output"
        );
        assert_eq!(
            out.sequences.num_patients() as usize,
            db.num_patients(),
            "{shape}/{kind}"
        );

        // --- byte-identical output across backends ---------------------
        let records = sorted(out.sequences.materialize().unwrap().records);
        let bytes = record_bytes(&records);
        match &golden {
            None => {
                golden = Some(bytes);
                golden_records = records;
            }
            Some(g) => assert_eq!(
                g,
                &bytes,
                "{shape}: {kind} diverged from {} on {} records",
                ALL_BACKENDS[0].1,
                golden_records.len()
            ),
        }

        // --- same plan, result pinned to spill files -------------------
        let spilled = Engine::from_dbmart(db.clone())
            .mine(MiningConfig {
                work_dir: work_dir(&format!("{shape}_{kind}_sp")),
                ..cfg.clone()
            })
            .backend(choice)
            .output(OutputChoice::Spilled)
            .out_dir(work_dir(&format!("{shape}_{kind}_spout")))
            .memory_budget(budget_bytes)
            .run()
            .unwrap_or_else(|e| panic!("{shape}/{kind}/spilled: {e}"));
        assert_eq!(spilled.report.output, OutputKind::Spilled, "{shape}/{kind}");
        assert_eq!(spilled.sequences.resident_bytes(), 0, "{shape}/{kind}");
        let sp = sorted(spilled.sequences.materialize().unwrap().records);
        assert_eq!(
            record_bytes(&sp),
            *golden.as_ref().expect("golden set above"),
            "{shape}/{kind}: materialized spilled output diverged"
        );
    }
    golden_records
}

/// Screened-path conformance: every screen implementation — the
/// production sort+compact, the paper's mark-and-truncate strategy, the
/// naive hash oracle, and the out-of-core external merge — must keep
/// byte-identical survivors (and identical stats) on this shape's
/// records, the external merge at every buffer bound.
fn assert_screens_conform(shape: &str, golden: &[SeqRecord]) {
    for min_patients in [1u32, 2, 4] {
        let cfg = SparsityConfig { min_patients, threads: 2 };
        // Feed every implementation an adversarial (reverse-sorted) order.
        let scrambled: Vec<SeqRecord> = golden.iter().rev().copied().collect();
        let mut a = scrambled.clone();
        let stats_a = sparsity::screen(&mut a, &cfg);
        let mut b = scrambled.clone();
        let stats_b = sparsity::screen_paper_strategy(&mut b, &cfg);
        let mut c = scrambled.clone();
        let stats_c = sparsity::screen_naive(&mut c, &cfg);
        let a = sorted(a);
        assert_eq!(
            record_bytes(&a),
            record_bytes(&sorted(b)),
            "{shape} t={min_patients}: paper strategy diverged"
        );
        assert_eq!(
            record_bytes(&a),
            record_bytes(&sorted(c)),
            "{shape} t={min_patients}: naive oracle diverged"
        );
        assert_eq!(stats_a, stats_b, "{shape} t={min_patients}");
        assert_eq!(stats_a, stats_c, "{shape} t={min_patients}");

        // Out-of-core: spill the records across three input files, screen
        // externally at several buffer bounds (1 KiB / 64 KiB /
        // unbounded), materialise, compare bytes and stats.
        let dir = work_dir(&format!("screens_{shape}_{min_patients}"));
        let input = spilled_input(&dir, &scrambled);
        for buffer_bytes in [1024u64, 64 * 1024, u64::MAX] {
            let spill_cfg = SpillScreenConfig {
                min_patients,
                threads: 2,
                buffer_bytes,
                out_dir: dir.join(format!("out_{buffer_bytes}")),
            };
            let (out, stats) = sparsity::screen_spilled(&input, &spill_cfg, None)
                .unwrap_or_else(|e| panic!("{shape} t={min_patients} buf={buffer_bytes}: {e}"));
            let got = sorted(out.read_all().unwrap());
            assert_eq!(
                record_bytes(&got),
                record_bytes(&a),
                "{shape} t={min_patients} buf={buffer_bytes}: spilled screen diverged"
            );
            assert_eq!(stats, stats_a, "{shape} t={min_patients} buf={buffer_bytes}");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// Targeted-mining conformance: for a battery of [`TargetSpec`]s, a
/// targeted run (predicate pushed into every backend's per-patient inner
/// loop, support counted within the targeted multiset) must be
/// **byte-identical** to the reference semantics `full mine → filter →
/// screen`, on every backend and at both residencies. `TargetSpec::all()`
/// must be the identity: byte-identical to an untargeted run.
fn assert_targeted_conform(
    shape: &str,
    mart: &DbMart,
    cfg: &MiningConfig,
    golden: &[SeqRecord],
) {
    use tspm_plus::target::{TargetPos, TargetSpec};
    let db = NumericDbMart::encode(mart);
    let nx = db.num_phenx() as u32;
    let fc = engine::forecast(&db, cfg);
    let floor = (fc.max_patient_sequences + 32) * 16;
    let budget = env_budget().unwrap_or(floor).max(floor);
    let sc = SparsityConfig { min_patients: 2, threads: 1 };

    // Duration-band-only spec works on every shape (even the empty
    // vocabulary); code specs need a non-empty encoded vocabulary or the
    // plan rightly rejects them.
    let mut specs = vec![TargetSpec::all().with_duration_band(None, Some(500))];
    if nx > 0 {
        specs.push(TargetSpec::for_codes([0, nx / 2]).with_pos(TargetPos::First));
        specs.push(
            TargetSpec::for_codes([nx - 1, 0])
                .with_pos(TargetPos::Second)
                .with_duration_band(Some(1), None),
        );
    }

    for (si, spec) in specs.iter().enumerate() {
        let mut reference: Vec<SeqRecord> =
            golden.iter().copied().filter(|r| spec.matches_record(r)).collect();
        let ref_stats = sparsity::screen(&mut reference, &sc);
        let reference = record_bytes(&sorted(reference));

        for (choice, kind) in ALL_BACKENDS {
            for spill in [false, true] {
                let tag = format!(
                    "{shape}_t{si}_{kind}_{}",
                    if spill { "sp" } else { "mem" }
                );
                let mut eng = Engine::from_dbmart(db.clone())
                    .mine(MiningConfig {
                        work_dir: work_dir(&format!("{tag}_mine")),
                        ..cfg.clone()
                    })
                    .target(spec.clone())
                    .screen(sc)
                    .backend(choice)
                    .memory_budget(budget);
                if spill {
                    eng = eng
                        .output(OutputChoice::Spilled)
                        .out_dir(work_dir(&format!("{tag}_out")));
                }
                let out = eng.run().unwrap_or_else(|e| panic!("{tag}: {e}"));
                assert_eq!(
                    out.screen_stats,
                    Some(ref_stats),
                    "{tag}: screen stats must be counted within the targeted multiset"
                );
                let got =
                    record_bytes(&sorted(out.sequences.materialize().unwrap().records));
                assert_eq!(
                    got, reference,
                    "{tag}: targeted output diverged from full-mine → filter → screen"
                );
            }
        }
    }

    for (choice, kind) in ALL_BACKENDS {
        let out = Engine::from_dbmart(db.clone())
            .mine(MiningConfig {
                work_dir: work_dir(&format!("{shape}_tall_{kind}")),
                ..cfg.clone()
            })
            .target(TargetSpec::all())
            .backend(choice)
            .memory_budget(budget)
            .run()
            .unwrap_or_else(|e| panic!("{shape}/all/{kind}: {e}"));
        let got = sorted(out.sequences.materialize().unwrap().records);
        assert_eq!(
            record_bytes(&got),
            record_bytes(golden),
            "{shape}/{kind}: TargetSpec::all() must be the identity"
        );
    }
}

/// Write `records` as a three-file spill set under `dir`.
fn spilled_input(dir: &Path, records: &[SeqRecord]) -> SeqFileSet {
    std::fs::create_dir_all(dir).unwrap();
    let chunk = records.len().div_ceil(3).max(1);
    let mut files = Vec::new();
    for (i, part) in records.chunks(chunk).enumerate() {
        let p = dir.join(format!("in_{i}.tspm"));
        seqstore::write_file(&p, part).unwrap();
        files.push(p);
    }
    if files.is_empty() {
        let p = dir.join("in_0.tspm");
        seqstore::write_file(&p, &[]).unwrap();
        files.push(p);
    }
    SeqFileSet {
        files,
        total_records: records.len() as u64,
        num_patients: 0,
        num_phenx: 0,
    }
}

// ---------------------------------------------------------------------------
// Adversarial shapes
// ---------------------------------------------------------------------------

/// Shape 1 — the empty cohort: zero patients, zero entries.
#[test]
fn conformance_empty_cohort() {
    let mart = DbMart::new(vec![]);
    let golden = assert_backends_conform("empty", &mart, &MiningConfig::default());
    assert!(golden.is_empty());
    assert_screens_conform("empty", &golden);
    assert_targeted_conform("empty", &mart, &MiningConfig::default(), &golden);
}

/// Shape 2 — single-entry patients only: every patient mines to zero
/// sequences, so any backend that fabricates or drops boundary chunks
/// shows up immediately.
#[test]
fn conformance_single_entry_patients() {
    let mart = DbMart::new(
        (0..40).map(|p| entry(&format!("p{p}"), p, &format!("x{}", p % 7))).collect(),
    );
    let golden = assert_backends_conform("single_entry", &mart, &MiningConfig::default());
    assert!(golden.is_empty(), "single-entry patients must yield no pairs");
    assert_screens_conform("single_entry", &golden);
    assert_targeted_conform("single_entry", &mart, &MiningConfig::default(), &golden);
}

/// Shape 3 — heavily skewed cohort: one 200-entry patient next to fifty
/// 1–3-entry patients. This is the shape dynamic scheduling exists for,
/// and the shape where static chunk/shard layouts disagree the most.
#[test]
fn conformance_heavily_skewed() {
    let mut entries = Vec::new();
    for i in 0..200 {
        entries.push(entry("whale", i, &format!("x{}", i % 23)));
    }
    let mut rng = Rng::new(42);
    for p in 0..50 {
        for i in 0..(1 + rng.gen_range(3)) {
            entries.push(entry(
                &format!("minnow{p}"),
                i as i32,
                &format!("x{}", rng.gen_range(23)),
            ));
        }
    }
    let mart = DbMart::new(entries);
    let golden = assert_backends_conform("skewed", &mart, &MiningConfig::default());
    assert!(golden.len() as u64 >= mining::pairs_for(200));
    assert_screens_conform("skewed", &golden);
    assert_targeted_conform("skewed", &mart, &MiningConfig::default(), &golden);
}

/// Shape 4 — duplicate timestamps: all of a patient's entries share one
/// date, so *every* pair is a tie and the orientation rests entirely on
/// the deterministic phenX tie-break. Run with the first-occurrence
/// filter too, which dedupes on top of the ties.
#[test]
fn conformance_duplicate_timestamps() {
    let mut entries = Vec::new();
    for p in 0..20 {
        for i in 0..10 {
            // Codes repeat within a patient (i % 4) to also exercise
            // same-code-same-date self pairs.
            entries.push(entry(&format!("p{p}"), 1000 + p, &format!("c{}", i % 4)));
        }
    }
    let mart = DbMart::new(entries);
    let golden = assert_backends_conform("dup_ts", &mart, &MiningConfig::default());
    assert!(golden.iter().all(|r| r.duration == 0), "same-date pairs must span 0 days");
    assert_screens_conform("dup_ts", &golden);
    assert_targeted_conform("dup_ts", &mart, &MiningConfig::default(), &golden);
    assert_backends_conform(
        "dup_ts_first",
        &mart,
        &MiningConfig { first_occurrence_only: true, ..Default::default() },
    );
}

/// Shape 5 — maximal durations: date spans close to `i32::MAX` days, so
/// duration values land in the top buckets of the u32 range, with a
/// coarse duration unit on top.
#[test]
fn conformance_max_duration_buckets() {
    let mut entries = Vec::new();
    for p in 0..8 {
        let pid = format!("p{p}");
        entries.push(entry(&pid, 0, "start"));
        entries.push(entry(&pid, 2_100_000_000, "end"));
        entries.push(entry(&pid, 1_000_000_000 + p, "mid"));
    }
    let mart = DbMart::new(entries);
    let golden = assert_backends_conform("max_dur", &mart, &MiningConfig::default());
    assert!(golden.iter().any(|r| r.duration >= 2_100_000_000), "top bucket missing");
    let monthly = assert_backends_conform(
        "max_dur_monthly",
        &mart,
        &MiningConfig { duration_unit_days: 30, ..Default::default() },
    );
    assert!(monthly.iter().all(|r| r.duration <= 2_100_000_000 / 30 + 1));
    assert_screens_conform("max_dur", &golden);
    assert_targeted_conform("max_dur", &mart, &MiningConfig::default(), &golden);
}

/// Shape 6 — randomized mixture: every adversarial trait at once, across
/// several seeds, with self-pairs excluded (the config under which the
/// forecast is only an upper bound).
#[test]
fn conformance_random_mixture() {
    for seed in 0..3u64 {
        let mut rng = Rng::new(0xBEEF + seed);
        let mut entries = Vec::new();
        let n_patients = 1 + rng.gen_range(30);
        for p in 0..n_patients {
            let n = match rng.gen_range(4) {
                0 => 1,
                1 => 2,
                _ => 1 + rng.gen_range(40),
            };
            let same_date = rng.gen_range(3) == 0;
            for _ in 0..n {
                let date = if same_date { 7 } else { rng.gen_range(3000) as i32 };
                entries.push(entry(
                    &format!("p{p}"),
                    date,
                    &format!("c{}", rng.gen_range(15)),
                ));
            }
        }
        let mart = DbMart::new(entries);
        let golden = assert_backends_conform(
            &format!("random{seed}"),
            &mart,
            &MiningConfig { include_self_pairs: false, ..Default::default() },
        );
        assert_screens_conform(&format!("random{seed}"), &golden);
        assert_targeted_conform(
            &format!("random{seed}"),
            &mart,
            &MiningConfig { include_self_pairs: false, ..Default::default() },
            &golden,
        );
    }
}

// ---------------------------------------------------------------------------
// Sharded determinism: output independent of thread and shard count
// ---------------------------------------------------------------------------

/// The sharded backend's promise, at two strengths. Strong form: for any
/// fixed `shards` setting (including auto = 0, whose layout is a
/// constant, never the worker count), the **raw, unsorted** output is
/// byte-identical for every worker count — the `TSPM_THREADS` axis that
/// CI drives by running this whole suite under `TSPM_THREADS=1` and
/// `=4` — because shards are merged in stable shard order, never
/// completion order. Weak form: across *different* shard layouts, the
/// sorted output is still byte-identical (same multiset, permuted).
#[test]
fn sharded_output_independent_of_threads_and_shards() {
    let mut entries = Vec::new();
    let mut rng = Rng::new(7);
    for i in 0..150 {
        entries.push(entry("whale", i, &format!("x{}", i % 11)));
    }
    for p in 0..30 {
        for i in 0..(1 + rng.gen_range(8)) {
            entries.push(entry(
                &format!("p{p}"),
                rng.gen_range(500) as i32,
                &format!("x{}", rng.gen_range(11)),
            ));
        }
    }
    let db = NumericDbMart::encode(&DbMart::new(entries));

    let golden = sorted(
        mining::mine_sequences_sharded(
            &db,
            &MiningConfig { threads: 1, shards: 1, ..Default::default() },
        )
        .unwrap()
        .records,
    );
    assert!(!golden.is_empty());
    let golden_bytes = record_bytes(&golden);
    for shards in [0usize, 1, 3, 8, 64] {
        let mut raw_golden: Option<Vec<u8>> = None;
        for threads in [1usize, 2, 8] {
            let cfg = MiningConfig { threads, shards, ..Default::default() };
            let got = mining::mine_sequences_sharded(&db, &cfg).unwrap().records;
            // Strong: raw order identical across thread counts.
            let raw = record_bytes(&got);
            match &raw_golden {
                None => raw_golden = Some(raw),
                Some(g) => assert_eq!(
                    g, &raw,
                    "shards={shards}: threads={threads} changed the RAW sharded output"
                ),
            }
            // Weak: sorted output identical across shard layouts too.
            assert_eq!(
                record_bytes(&sorted(got)),
                golden_bytes,
                "threads={threads} shards={shards} changed the sharded multiset"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Spill-aware engine results (the out-of-core contract)
// ---------------------------------------------------------------------------

/// The headline acceptance test for the out-of-core contract: a
/// FileBacked run whose memory budget is far below the predicted
/// (post-screen upper bound) output must complete end to end with its
/// `MemTracker` peak under the budget, auto-spill its result, and
/// `materialize()` to bytes identical to the InMemory backend's screened
/// result.
#[test]
fn spilled_filebacked_screen_stays_under_budget_and_matches_in_memory() {
    // 300 patients × 80 entries → ~948k records ≈ 15 MB of output;
    // overlapping code assignments make most sequences survive a
    // 2-patient screen, so the post-screen output still dwarfs the
    // budget.
    let mut entries = Vec::new();
    for p in 0..300 {
        for i in 0..80 {
            entries.push(entry(&format!("p{p}"), i, &format!("x{}", (i * 7 + p) % 120)));
        }
    }
    let mart = DbMart::new(entries);
    let db = NumericDbMart::encode(&mart);
    let mine_cfg = MiningConfig {
        threads: 1,
        work_dir: work_dir("budget_mine"),
        ..Default::default()
    };
    let fc = engine::forecast(&db, &mine_cfg);
    let budget = 6u64 << 20;
    assert!(
        fc.total_bytes > 2 * budget,
        "cohort too small: forecast {} must dwarf the {budget} budget",
        fc.total_bytes
    );
    let sc = SparsityConfig { min_patients: 2, threads: 1 };

    let spilled = Engine::from_dbmart(db.clone())
        .mine(mine_cfg)
        .screen(sc)
        .backend(BackendChoice::FileBacked)
        .out_dir(work_dir("budget_out"))
        .memory_budget(budget)
        .run()
        .unwrap();
    assert_eq!(spilled.report.backend, BackendKind::FileBacked);
    assert_eq!(spilled.report.output, OutputKind::Spilled);
    assert!(
        spilled.report.peak_logical_bytes <= budget,
        "peak {} exceeds the {budget} budget",
        spilled.report.peak_logical_bytes
    );
    let stage_names: Vec<&str> =
        spilled.report.stages.iter().map(|s| s.stage.as_str()).collect();
    assert_eq!(stage_names, ["mine", "screen"]);

    let in_mem = Engine::from_dbmart(db)
        .mine(MiningConfig {
            threads: 1,
            work_dir: work_dir("budget_mem"),
            ..Default::default()
        })
        .screen(sc)
        .backend(BackendChoice::InMemory)
        .memory_budget(u64::MAX)
        .run()
        .unwrap();
    assert_eq!(in_mem.report.output, OutputKind::InMemory);
    assert_eq!(spilled.screen_stats, in_mem.screen_stats);

    let a = sorted(spilled.sequences.materialize().unwrap().records);
    let b = sorted(in_mem.sequences.materialize().unwrap().records);
    assert!(!a.is_empty(), "the 2-patient screen must keep something");
    assert_eq!(record_bytes(&a), record_bytes(&b));
}

/// External-merge determinism: for random record sets, `screen_spilled`
/// writes the *identical file* (not just the same multiset) at every
/// buffer bound — 1 KiB, 64 KiB, unbounded — because the merge orders on
/// the full `(seq, pid, duration)` key. Stats and survivors also match
/// the in-memory screen.
#[test]
fn external_merge_screen_is_deterministic_across_buffer_sizes() {
    for case in 0..5u64 {
        let mut rng = Rng::new(0xF00D + case);
        let n = 2_000 + rng.gen_range(20_000) as usize;
        let records: Vec<SeqRecord> = (0..n)
            .map(|_| SeqRecord {
                seq: rng.gen_range(300),
                pid: rng.gen_range(80) as u32,
                duration: rng.gen_range(2_000) as u32,
            })
            .collect();
        let threshold = 1 + rng.gen_range(6) as u32;

        let mut expect = records.clone();
        let expect_stats = sparsity::screen(
            &mut expect,
            &SparsityConfig { min_patients: threshold, threads: 1 },
        );
        let expect = sorted(expect);

        let dir = work_dir(&format!("merge_det_{case}"));
        let input = spilled_input(&dir, &records);
        let mut golden_file: Option<Vec<SeqRecord>> = None;
        for buffer_bytes in [1024u64, 64 * 1024, u64::MAX] {
            let cfg = SpillScreenConfig {
                min_patients: threshold,
                threads: 1 + (case as usize % 3),
                buffer_bytes,
                out_dir: dir.join(format!("out_{buffer_bytes}")),
            };
            let (out, stats) = sparsity::screen_spilled(&input, &cfg, None).unwrap();
            assert_eq!(stats, expect_stats, "case={case} buf={buffer_bytes}");
            // Raw file order, no re-sort: determinism is byte-literal.
            let got = out.read_all().unwrap();
            match &golden_file {
                None => golden_file = Some(got.clone()),
                Some(g) => assert_eq!(
                    g, &got,
                    "case={case} buf={buffer_bytes}: buffer size changed the output file"
                ),
            }
            assert_eq!(sorted(got), expect, "case={case} buf={buffer_bytes}");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}

// ---------------------------------------------------------------------------
// Tracing is a pure observer
// ---------------------------------------------------------------------------

/// Attaching a live tracer must not change one byte of any backend's
/// output: spans time the stages, they never touch the data path. The
/// traced run writes into a [`MemorySink`] and the test also pins that
/// spans really were emitted — a silently disabled tracer would make
/// the byte comparison vacuous. (CI additionally re-runs this whole
/// suite under `TSPM_TRACE=1`, which routes every *untraced* engine's
/// `Tracer::from_env` to stderr JSONL.)
#[test]
fn traced_run_output_is_byte_identical_to_untraced() {
    let mut rng = Rng::new(0x7ACE);
    let mut entries = Vec::new();
    for p in 0..20 {
        for _ in 0..(1 + rng.gen_range(25)) {
            entries.push(entry(
                &format!("p{p}"),
                rng.gen_range(2_000) as i32,
                &format!("c{}", rng.gen_range(12)),
            ));
        }
    }
    let db = NumericDbMart::encode(&DbMart::new(entries));
    let cfg = MiningConfig { include_self_pairs: false, ..Default::default() };
    let fc = engine::forecast(&db, &cfg);
    let floor = (fc.max_patient_sequences + 32) * 16;
    let budget = env_budget().unwrap_or(floor).max(floor);

    for (choice, kind) in ALL_BACKENDS {
        let untraced = Engine::from_dbmart(db.clone())
            .mine(MiningConfig { work_dir: work_dir(&format!("untraced_{kind}")), ..cfg.clone() })
            .backend(choice)
            .memory_budget(budget)
            .tracer(tspm_plus::obs::Tracer::disabled())
            .run()
            .unwrap_or_else(|e| panic!("untraced/{kind}: {e}"));

        let sink = std::sync::Arc::new(tspm_plus::obs::MemorySink::new());
        let traced = Engine::from_dbmart(db.clone())
            .mine(MiningConfig { work_dir: work_dir(&format!("traced_{kind}")), ..cfg.clone() })
            .backend(choice)
            .memory_budget(budget)
            .tracer(tspm_plus::obs::Tracer::new(sink.clone()))
            .run()
            .unwrap_or_else(|e| panic!("traced/{kind}: {e}"));

        let a = sorted(untraced.sequences.materialize().unwrap().records);
        let b = sorted(traced.sequences.materialize().unwrap().records);
        assert!(!a.is_empty(), "{kind}: fixture mined nothing");
        assert_eq!(record_bytes(&a), record_bytes(&b), "{kind}: tracing changed the output");

        let lines = sink.lines();
        assert!(
            lines.iter().any(|l| l.contains("\"name\":\"engine.run\"")),
            "{kind}: traced run emitted no engine.run span: {lines:?}"
        );
    }
}
