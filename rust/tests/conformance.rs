//! Cross-backend conformance harness.
//!
//! The engine promises that its four execution backends (in-memory,
//! sharded, file-backed, streaming) are *interchangeable*: same plan in,
//! same sequence multiset out, whatever the scheduling, spill format, or
//! thread count. Reordering bugs are exactly the class that slips past
//! happy-path tests, so this harness feeds **adversarial dbmart shapes**
//! — empty cohorts, single-entry patients, heavily skewed patients,
//! duplicate timestamps, maximal durations — through every backend and
//! asserts **byte-identical** sorted output plus the `RunReport`
//! invariants each run must satisfy.
//!
//! Every future backend (async, caching, remote) gets wired into
//! `ALL_BACKENDS` below and inherits the whole battery.

use tspm_plus::dbmart::{DbMart, DbMartEntry, NumericDbMart};
use tspm_plus::engine::{self, BackendChoice, BackendKind, Engine};
use tspm_plus::mining::{self, MiningConfig, SeqRecord};
use tspm_plus::rng::Rng;

/// Every backend the engine can execute, paired with the kind the report
/// must name.
const ALL_BACKENDS: [(BackendChoice, BackendKind); 4] = [
    (BackendChoice::InMemory, BackendKind::InMemory),
    (BackendChoice::Sharded, BackendKind::Sharded),
    (BackendChoice::FileBacked, BackendKind::FileBacked),
    (BackendChoice::Streaming, BackendKind::Streaming),
];

fn entry(p: &str, date: i32, x: &str) -> DbMartEntry {
    DbMartEntry { patient_id: p.into(), date, phenx: x.into(), description: None }
}

fn sorted(mut v: Vec<SeqRecord>) -> Vec<SeqRecord> {
    v.sort_unstable_by_key(|r| (r.seq, r.pid, r.duration));
    v
}

/// Serialize sorted records to their canonical little-endian byte layout
/// so "byte-identical" is literal, not just field-wise equality.
fn record_bytes(records: &[SeqRecord]) -> Vec<u8> {
    let mut out = Vec::with_capacity(records.len() * 16);
    for r in records {
        out.extend_from_slice(&r.seq.to_le_bytes());
        out.extend_from_slice(&r.pid.to_le_bytes());
        out.extend_from_slice(&r.duration.to_le_bytes());
    }
    out
}

/// Unique spill directory per (shape, backend) so concurrently running
/// tests never share file names.
fn work_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("tspm_conf_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// The harness core: run the identical plan through all four backends and
/// assert byte-identical sorted output and the `RunReport` invariants.
/// Returns the golden sorted records for shape-specific follow-up checks.
fn assert_backends_conform(shape: &str, mart: &DbMart, cfg: &MiningConfig) -> Vec<SeqRecord> {
    let db = NumericDbMart::encode(mart);
    // A budget that clears the largest single patient (streaming would
    // otherwise legitimately refuse) but sits below most totals, so the
    // streaming run really partitions.
    let fc = engine::forecast(&db, cfg);
    let budget_bytes = (fc.max_patient_sequences + 32) * 16;

    let mut golden: Option<Vec<u8>> = None;
    let mut golden_records = Vec::new();
    for (choice, kind) in ALL_BACKENDS {
        let run_cfg = MiningConfig {
            work_dir: work_dir(&format!("{shape}_{kind}")),
            ..cfg.clone()
        };
        let out = Engine::from_dbmart(db.clone())
            .mine(run_cfg)
            .backend(choice)
            .memory_budget(budget_bytes)
            .run()
            .unwrap_or_else(|e| panic!("{shape}/{kind}: {e}"));

        // --- RunReport invariants, identical for every backend ---------
        assert_eq!(out.report.backend, kind, "{shape}: report names the wrong backend");
        let stage_names: Vec<&str> =
            out.report.stages.iter().map(|s| s.stage.as_str()).collect();
        assert_eq!(stage_names, ["mine"], "{shape}/{kind}");
        assert_eq!(
            out.report.stages[0].records_out,
            out.sequences.len() as u64,
            "{shape}/{kind}: mine stage under/over-reports records"
        );
        assert_eq!(
            out.report.stages[0].bytes_out,
            out.sequences.byte_size(),
            "{shape}/{kind}"
        );
        assert_eq!(out.report.forecast, fc, "{shape}/{kind}: forecast drifted");
        if cfg.include_self_pairs {
            assert_eq!(
                fc.total_sequences,
                out.sequences.len() as u64,
                "{shape}/{kind}: forecast must be exact with self-pairs"
            );
        } else {
            assert!(fc.total_sequences >= out.sequences.len() as u64, "{shape}/{kind}");
        }
        assert!(
            out.report.peak_logical_bytes >= out.sequences.byte_size(),
            "{shape}/{kind}: peak below the materialised output"
        );
        assert_eq!(out.sequences.num_patients as usize, db.num_patients(), "{shape}/{kind}");

        // --- byte-identical output across backends ---------------------
        let records = sorted(out.sequences.records);
        let bytes = record_bytes(&records);
        match &golden {
            None => {
                golden = Some(bytes);
                golden_records = records;
            }
            Some(g) => assert_eq!(
                g,
                &bytes,
                "{shape}: {kind} diverged from {} on {} records",
                ALL_BACKENDS[0].1,
                golden_records.len()
            ),
        }
    }
    golden_records
}

// ---------------------------------------------------------------------------
// Adversarial shapes
// ---------------------------------------------------------------------------

/// Shape 1 — the empty cohort: zero patients, zero entries.
#[test]
fn conformance_empty_cohort() {
    let mart = DbMart::new(vec![]);
    let golden = assert_backends_conform("empty", &mart, &MiningConfig::default());
    assert!(golden.is_empty());
}

/// Shape 2 — single-entry patients only: every patient mines to zero
/// sequences, so any backend that fabricates or drops boundary chunks
/// shows up immediately.
#[test]
fn conformance_single_entry_patients() {
    let mart = DbMart::new(
        (0..40).map(|p| entry(&format!("p{p}"), p, &format!("x{}", p % 7))).collect(),
    );
    let golden = assert_backends_conform("single_entry", &mart, &MiningConfig::default());
    assert!(golden.is_empty(), "single-entry patients must yield no pairs");
}

/// Shape 3 — heavily skewed cohort: one 200-entry patient next to fifty
/// 1–3-entry patients. This is the shape dynamic scheduling exists for,
/// and the shape where static chunk/shard layouts disagree the most.
#[test]
fn conformance_heavily_skewed() {
    let mut entries = Vec::new();
    for i in 0..200 {
        entries.push(entry("whale", i, &format!("x{}", i % 23)));
    }
    let mut rng = Rng::new(42);
    for p in 0..50 {
        for i in 0..(1 + rng.gen_range(3)) {
            entries.push(entry(
                &format!("minnow{p}"),
                i as i32,
                &format!("x{}", rng.gen_range(23)),
            ));
        }
    }
    let mart = DbMart::new(entries);
    let golden = assert_backends_conform("skewed", &mart, &MiningConfig::default());
    assert!(golden.len() as u64 >= mining::pairs_for(200));
}

/// Shape 4 — duplicate timestamps: all of a patient's entries share one
/// date, so *every* pair is a tie and the orientation rests entirely on
/// the deterministic phenX tie-break. Run with the first-occurrence
/// filter too, which dedupes on top of the ties.
#[test]
fn conformance_duplicate_timestamps() {
    let mut entries = Vec::new();
    for p in 0..20 {
        for i in 0..10 {
            // Codes repeat within a patient (i % 4) to also exercise
            // same-code-same-date self pairs.
            entries.push(entry(&format!("p{p}"), 1000 + p, &format!("c{}", i % 4)));
        }
    }
    let mart = DbMart::new(entries);
    let golden = assert_backends_conform("dup_ts", &mart, &MiningConfig::default());
    assert!(golden.iter().all(|r| r.duration == 0), "same-date pairs must span 0 days");
    assert_backends_conform(
        "dup_ts_first",
        &mart,
        &MiningConfig { first_occurrence_only: true, ..Default::default() },
    );
}

/// Shape 5 — maximal durations: date spans close to `i32::MAX` days, so
/// duration values land in the top buckets of the u32 range, with a
/// coarse duration unit on top.
#[test]
fn conformance_max_duration_buckets() {
    let mut entries = Vec::new();
    for p in 0..8 {
        let pid = format!("p{p}");
        entries.push(entry(&pid, 0, "start"));
        entries.push(entry(&pid, 2_100_000_000, "end"));
        entries.push(entry(&pid, 1_000_000_000 + p, "mid"));
    }
    let mart = DbMart::new(entries);
    let golden = assert_backends_conform("max_dur", &mart, &MiningConfig::default());
    assert!(golden.iter().any(|r| r.duration >= 2_100_000_000), "top bucket missing");
    let monthly = assert_backends_conform(
        "max_dur_monthly",
        &mart,
        &MiningConfig { duration_unit_days: 30, ..Default::default() },
    );
    assert!(monthly.iter().all(|r| r.duration <= 2_100_000_000 / 30 + 1));
}

/// Shape 6 — randomized mixture: every adversarial trait at once, across
/// several seeds, with self-pairs excluded (the config under which the
/// forecast is only an upper bound).
#[test]
fn conformance_random_mixture() {
    for seed in 0..3u64 {
        let mut rng = Rng::new(0xBEEF + seed);
        let mut entries = Vec::new();
        let n_patients = 1 + rng.gen_range(30);
        for p in 0..n_patients {
            let n = match rng.gen_range(4) {
                0 => 1,
                1 => 2,
                _ => 1 + rng.gen_range(40),
            };
            let same_date = rng.gen_range(3) == 0;
            for _ in 0..n {
                let date = if same_date { 7 } else { rng.gen_range(3000) as i32 };
                entries.push(entry(
                    &format!("p{p}"),
                    date,
                    &format!("c{}", rng.gen_range(15)),
                ));
            }
        }
        let mart = DbMart::new(entries);
        assert_backends_conform(
            &format!("random{seed}"),
            &mart,
            &MiningConfig { include_self_pairs: false, ..Default::default() },
        );
    }
}

// ---------------------------------------------------------------------------
// Sharded determinism: output independent of thread and shard count
// ---------------------------------------------------------------------------

/// The sharded backend's promise, at two strengths. Strong form: for any
/// fixed `shards` setting (including auto = 0, whose layout is a
/// constant, never the worker count), the **raw, unsorted** output is
/// byte-identical for every worker count — the `TSPM_THREADS` axis that
/// CI drives by running this whole suite under `TSPM_THREADS=1` and
/// `=4` — because shards are merged in stable shard order, never
/// completion order. Weak form: across *different* shard layouts, the
/// sorted output is still byte-identical (same multiset, permuted).
#[test]
fn sharded_output_independent_of_threads_and_shards() {
    let mut entries = Vec::new();
    let mut rng = Rng::new(7);
    for i in 0..150 {
        entries.push(entry("whale", i, &format!("x{}", i % 11)));
    }
    for p in 0..30 {
        for i in 0..(1 + rng.gen_range(8)) {
            entries.push(entry(
                &format!("p{p}"),
                rng.gen_range(500) as i32,
                &format!("x{}", rng.gen_range(11)),
            ));
        }
    }
    let db = NumericDbMart::encode(&DbMart::new(entries));

    let golden = sorted(
        mining::mine_sequences_sharded(
            &db,
            &MiningConfig { threads: 1, shards: 1, ..Default::default() },
        )
        .unwrap()
        .records,
    );
    assert!(!golden.is_empty());
    let golden_bytes = record_bytes(&golden);
    for shards in [0usize, 1, 3, 8, 64] {
        let mut raw_golden: Option<Vec<u8>> = None;
        for threads in [1usize, 2, 8] {
            let cfg = MiningConfig { threads, shards, ..Default::default() };
            let got = mining::mine_sequences_sharded(&db, &cfg).unwrap().records;
            // Strong: raw order identical across thread counts.
            let raw = record_bytes(&got);
            match &raw_golden {
                None => raw_golden = Some(raw),
                Some(g) => assert_eq!(
                    g, &raw,
                    "shards={shards}: threads={threads} changed the RAW sharded output"
                ),
            }
            // Weak: sorted output identical across shard layouts too.
            assert_eq!(
                record_bytes(&sorted(got)),
                golden_bytes,
                "threads={threads} shards={shards} changed the sharded multiset"
            );
        }
    }
}
