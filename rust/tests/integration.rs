//! Cross-module integration tests: CSV → encode → mine → screen →
//! store → matrix → analytics, in various combinations, plus failure
//! injection.

use std::collections::BTreeSet;

use tspm_plus::baseline::{self, BaselineConfig};
use tspm_plus::dbmart::{decode_seq, DbMart, DbMartEntry, LookupTables, NumericDbMart};
use tspm_plus::matrix::SeqMatrix;
use tspm_plus::mining::{self, MiningConfig, MiningMode};
use tspm_plus::partition;
use tspm_plus::pipeline::{self, PipelineConfig};
use tspm_plus::seqstore;
use tspm_plus::sparsity::{self, SparsityConfig};
use tspm_plus::synthea::{Scenario, SyntheaConfig};
use tspm_plus::util;

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("tspm_it_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// The full batch path through disk: CSV round-trip, mine, screen, store,
/// reload, rebuild the matrix — every representation change preserved.
#[test]
fn csv_mine_screen_store_roundtrip() {
    let dir = tmpdir("roundtrip");
    let cohort = SyntheaConfig::small().generate();
    let csv = dir.join("mart.csv");
    cohort.write_csv(&csv).unwrap();
    let reloaded = DbMart::read_csv(&csv).unwrap();
    assert_eq!(reloaded.len(), cohort.len());

    let db = NumericDbMart::encode(&reloaded);
    let mined = mining::mine_sequences(&db, &MiningConfig::default()).unwrap();
    let mut records = mined.records;
    let stats = sparsity::screen(&mut records, &SparsityConfig { min_patients: 4, threads: 2 });
    assert!(stats.records_after > 0);

    let store = dir.join("seqs.tspm");
    seqstore::write_file(&store, &records).unwrap();
    let back = seqstore::read_file(&store).unwrap();
    assert_eq!(back, records);

    let m = SeqMatrix::build(&back, db.num_patients() as u32).unwrap();
    assert_eq!(m.num_cols() as u64, stats.distinct_after);
    // every record is represented
    for r in back.iter().take(500) {
        let col = m.seq_ids.binary_search(&r.seq).unwrap();
        assert!(m.get(r.pid, col as u32));
    }
}

/// Lookup tables survive JSON round-trip and still translate mined ids.
#[test]
fn lookup_translation_after_json_roundtrip() {
    let cohort = SyntheaConfig::small().generate();
    let db = NumericDbMart::encode(&cohort);
    let json = db.lookup.to_json().to_string_pretty();
    let lookup = LookupTables::from_json(&tspm_plus::json::Json::parse(&json).unwrap()).unwrap();
    let mined = mining::mine_sequences(&db, &MiningConfig::default()).unwrap();
    let r = mined.records[mined.len() / 3];
    let (s, e) = decode_seq(r.seq);
    assert_eq!(lookup.phenx_name(s), db.lookup.phenx_name(s));
    assert_eq!(lookup.phenx_name(e), db.lookup.phenx_name(e));
    assert_eq!(lookup.patient_name(r.pid), db.lookup.patient_name(r.pid));
}

/// All five mining paths (memory/sharded/file × batch/pipeline) agree
/// exactly.
#[test]
fn five_mining_paths_agree() {
    let cohort = SyntheaConfig::small().generate();
    let db = NumericDbMart::encode(&cohort);

    let key = |r: &mining::SeqRecord| (r.seq, r.pid, r.duration);
    let mut batch_mem = mining::mine_sequences(&db, &MiningConfig::default()).unwrap().records;
    batch_mem.sort_unstable_by_key(key);

    let cfg_file = MiningConfig {
        mode: MiningMode::FileBased,
        work_dir: tmpdir("fourpaths"),
        ..Default::default()
    };
    let files = mining::mine_sequences_to_files(&db, &cfg_file).unwrap();
    let mut batch_file = files.read_all().unwrap();
    batch_file.sort_unstable_by_key(key);
    assert_eq!(batch_mem, batch_file);

    let mut streamed = pipeline::run(
        &db,
        &PipelineConfig { chunk_cap: 60_000, shards: 3, ..Default::default() },
    )
    .unwrap()
    .sequences
    .materialize()
    .unwrap()
    .records;
    streamed.sort_unstable_by_key(key);
    assert_eq!(batch_mem, streamed);

    let mut partitioned =
        partition::mine_partitioned(&db, &MiningConfig::default(), 60_000, None)
            .unwrap()
            .records;
    partitioned.sort_unstable_by_key(key);
    assert_eq!(batch_mem, partitioned);

    let mut sharded = mining::mine_sequences_sharded(
        &db,
        &MiningConfig { shards: 6, threads: 3, ..Default::default() },
    )
    .unwrap()
    .records;
    sharded.sort_unstable_by_key(key);
    assert_eq!(batch_mem, sharded);
}

/// Baseline and tSPM+ produce identical screened sequence *sets* on
/// tie-free data (F1 + screening integration).
#[test]
fn baseline_and_plus_agree_after_screening() {
    let mut cohort = SyntheaConfig::small().generate();
    let mut seen = std::collections::HashSet::new();
    cohort.entries.retain(|e| seen.insert((e.patient_id.clone(), e.date)));

    let threshold = 4u32;
    let base = baseline::mine(
        &cohort,
        &BaselineConfig {
            first_occurrence_only: true,
            sparsity_screen: true,
            min_patients: threshold,
        },
    );
    let base_set: BTreeSet<(String, String)> = base
        .sequences
        .iter()
        .map(|s| (s.patient.clone(), s.sequence.clone()))
        .collect();

    let db = NumericDbMart::encode(&cohort);
    let mut plus = mining::mine_sequences(
        &db,
        &MiningConfig { first_occurrence_only: true, ..Default::default() },
    )
    .unwrap()
    .records;
    sparsity::screen(&mut plus, &SparsityConfig { min_patients: threshold, threads: 1 });
    let plus_set: BTreeSet<(String, String)> = plus
        .iter()
        .map(|r| {
            let (s, e) = decode_seq(r.seq);
            (
                db.lookup.patient_name(r.pid).to_string(),
                format!("{}->{}", db.lookup.phenx_name(s), db.lookup.phenx_name(e)),
            )
        })
        .collect();
    assert_eq!(base_set, plus_set);
}

/// Screening a file-based result equals screening the in-memory result.
#[test]
fn file_based_screen_equals_memory_screen() {
    let cohort = SyntheaConfig::small().generate();
    let db = NumericDbMart::encode(&cohort);
    let sc = SparsityConfig { min_patients: 5, threads: 2 };

    let mut mem = mining::mine_sequences(&db, &MiningConfig::default()).unwrap().records;
    let mem_stats = sparsity::screen(&mut mem, &sc);

    let cfg = MiningConfig {
        mode: MiningMode::FileBased,
        work_dir: tmpdir("screenfile"),
        ..Default::default()
    };
    let files = mining::mine_sequences_to_files(&db, &cfg).unwrap();
    let mut from_file = files.read_all().unwrap();
    let file_stats = sparsity::screen(&mut from_file, &sc);

    assert_eq!(mem_stats, file_stats);
    mem.sort_unstable_by_key(|r| (r.seq, r.pid, r.duration));
    from_file.sort_unstable_by_key(|r| (r.seq, r.pid, r.duration));
    assert_eq!(mem, from_file);
}

/// Utility filters compose with mining output (transitive end-set on a
/// crafted trajectory).
#[test]
fn utilities_on_mined_output() {
    let raw = DbMart::new(vec![
        DbMartEntry { patient_id: "p".into(), date: 0, phenx: "covid".into(), description: None },
        DbMartEntry { patient_id: "p".into(), date: 90, phenx: "fatigue".into(), description: None },
        DbMartEntry { patient_id: "p".into(), date: 170, phenx: "fatigue".into(), description: None },
        DbMartEntry { patient_id: "q".into(), date: 5, phenx: "anemia".into(), description: None },
        DbMartEntry { patient_id: "q".into(), date: 30, phenx: "fatigue".into(), description: None },
    ]);
    let db = NumericDbMart::encode(&raw);
    let mined = mining::mine_sequences(&db, &MiningConfig::default()).unwrap();
    let covid = db.lookup.phenx_id("covid").unwrap();
    let fatigue = db.lookup.phenx_id("fatigue").unwrap();

    // end-set of covid = {fatigue}; transitive end sequences must include
    // q's anemia→fatigue even though q never had covid.
    let ends = util::end_set_of(&mined.records, covid);
    assert_eq!(ends, BTreeSet::from([fatigue]));
    let transitive = util::transitive_end_sequences(&mined.records, covid);
    let pids: BTreeSet<u32> = transitive.iter().map(|r| r.pid).collect();
    assert_eq!(pids.len(), 2, "both patients' fatigue-ending sequences included");
    // durations: covid→fatigue twice for p with span 80
    let spans = util::duration_span_per_patient(
        &mined.records,
        tspm_plus::dbmart::encode_seq(covid, fatigue),
    );
    assert_eq!(spans[&db.entries[0].patient], 80);
}

/// Failure injection: corrupted store files, truncated files, missing
/// columns — every error surfaces as Err, never panics or silent data.
#[test]
fn failure_injection_store_and_csv() {
    let dir = tmpdir("failures");

    // corrupt magic
    let bad_magic = dir.join("bad_magic.tspm");
    std::fs::write(&bad_magic, b"GARBAGE!0000000000000000").unwrap();
    assert!(seqstore::read_file(&bad_magic).is_err());

    // truncated payload
    let trunc = dir.join("trunc.tspm");
    let records: Vec<mining::SeqRecord> =
        (0..100).map(|i| mining::SeqRecord { seq: i, pid: 0, duration: 0 }).collect();
    seqstore::write_file(&trunc, &records).unwrap();
    let bytes = std::fs::read(&trunc).unwrap();
    std::fs::write(&trunc, &bytes[..bytes.len() - 10]).unwrap();
    assert!(seqstore::read_file(&trunc).is_err());

    // CSV with missing required column
    let bad_csv = dir.join("bad.csv");
    std::fs::write(&bad_csv, "patient_num,phenx\np1,x\n").unwrap();
    assert!(DbMart::read_csv(&bad_csv).is_err());

    // CSV with malformed date
    let bad_date = dir.join("bad_date.csv");
    std::fs::write(&bad_date, "patient_num,start_date,phenx\np1,NOTADATE,x\n").unwrap();
    assert!(DbMart::read_csv(&bad_date).is_err());

    // vocabulary overflow is surfaced, not silently wrapped
    // (construct synthetically: MAX_PHENX entries can't be allocated here,
    // so check the plan-level gate instead)
    let db = NumericDbMart::encode(&DbMart::new(
        (0..100)
            .map(|i| DbMartEntry {
                patient_id: "p".into(),
                date: i,
                phenx: format!("x{i}"),
                description: None,
            })
            .collect(),
    ));
    assert!(matches!(
        partition::plan(&db, &MiningConfig::default(), 10),
        Err(partition::PartitionError::PatientExceedsCap { .. })
    ));
}

/// Generic scenario + duration units + self-pair exclusion combine.
#[test]
fn config_combinations() {
    let mut gen_cfg = SyntheaConfig::mgb_like(0.01);
    gen_cfg.scenario = Scenario::Generic;
    let db = NumericDbMart::encode(&gen_cfg.generate());
    for unit in [1u32, 7, 30] {
        for self_pairs in [true, false] {
            for first_only in [true, false] {
                let cfg = MiningConfig {
                    duration_unit_days: unit,
                    include_self_pairs: self_pairs,
                    first_occurrence_only: first_only,
                    ..Default::default()
                };
                let got = mining::mine_sequences(&db, &cfg).unwrap();
                if !self_pairs {
                    assert!(got.records.iter().all(|r| {
                        let (s, e) = decode_seq(r.seq);
                        s != e
                    }));
                }
                if unit == 30 {
                    // horizon 3650 days → at most 122 months
                    assert!(got.records.iter().all(|r| r.duration <= 3650 / 30 + 1));
                }
            }
        }
    }
}

/// Matrix/selection pipeline stays consistent under column projection.
#[test]
fn matrix_projection_consistency() {
    let cohort = SyntheaConfig::small().generate();
    let db = NumericDbMart::encode(&cohort);
    let mut records = mining::mine_sequences(&db, &MiningConfig::default()).unwrap().records;
    sparsity::screen(&mut records, &SparsityConfig { min_patients: 10, threads: 0 });
    let m = SeqMatrix::build(&records, db.num_patients() as u32).unwrap();
    let cols: Vec<u32> = (0..m.num_cols() as u32).step_by(3).collect();
    let sub = m.select_columns(&cols);
    for (new_col, &old_col) in cols.iter().enumerate() {
        assert_eq!(sub.seq_ids[new_col as usize], m.seq_ids[old_col as usize]);
        for pid in (0..m.num_patients).step_by(7) {
            assert_eq!(sub.get(pid, new_col as u32), m.get(pid, old_col));
        }
    }
}

/// The engine façade composes with the stored-file workflow: a
/// config-driven run writes the same screened set the expert layer
/// produces, and the deprecated error alias still names the unified type.
#[test]
fn engine_from_config_matches_expert_layer() {
    use tspm_plus::config::RunConfig;
    use tspm_plus::engine::Engine;

    let cohort = SyntheaConfig::small().generate();
    let db = NumericDbMart::encode(&cohort);

    let mut cfg = RunConfig::default();
    cfg.sparsity_min_patients = 6;
    cfg.threads = 2;
    let out = Engine::from_config(db.clone(), &cfg).unwrap().run().unwrap();

    let mut expert = mining::mine_sequences(&db, &cfg.mining_config()).unwrap().records;
    sparsity::screen(&mut expert, &cfg.sparsity_config().unwrap());

    let key = |r: &mining::SeqRecord| (r.seq, r.pid, r.duration);
    let mut got = out.sequences.materialize().unwrap().records;
    got.sort_unstable_by_key(key);
    expert.sort_unstable_by_key(key);
    assert_eq!(got, expert);

    // The report names the canonical stages.
    let names: Vec<&str> = out.report.stages.iter().map(|s| s.stage.as_str()).collect();
    assert_eq!(names, ["mine", "screen"]);

    // Deprecated alias resolves to the unified error type for one release.
    #[allow(deprecated)]
    fn takes_legacy(e: tspm_plus::partition::MiningErrorOrPartition) -> tspm_plus::engine::TspmError {
        e
    }
    let legacy = takes_legacy(tspm_plus::engine::TspmError::Plan("x".into()));
    assert!(matches!(legacy, tspm_plus::engine::TspmError::Plan(_)));
}
