//! Integration over the PJRT runtime: the AOT artifacts loaded from
//! `artifacts/` must agree with the pure-Rust analytic oracles on real
//! mined data. Skipped (with a note) when `make artifacts` has not run.
//!
//! The whole suite is quarantined behind the `pjrt` cargo feature — it
//! needs the external `xla` crate and AOT-compiled HLO artifacts, neither
//! of which exist in a plain checkout (the default build compiles the
//! runtime stubs instead).
#![cfg(feature = "pjrt")]

use tspm_plus::dbmart::NumericDbMart;
use tspm_plus::matrix::SeqMatrix;
use tspm_plus::mining::{mine_sequences, MiningConfig};
use tspm_plus::ml;
use tspm_plus::msmr::{self, MsmrConfig};
use tspm_plus::runtime::{default_artifacts_dir, ArtifactSet, Tensor};
use tspm_plus::sparsity::{self, SparsityConfig};
use tspm_plus::synthea::SyntheaConfig;

fn artifacts() -> Option<ArtifactSet> {
    let dir = default_artifacts_dir();
    if dir.join("manifest.json").exists() {
        Some(ArtifactSet::load(&dir).expect("artifact load"))
    } else {
        eprintln!("skipping: artifacts/ missing — run `make artifacts`");
        None
    }
}

fn mined_matrix() -> (SeqMatrix, Vec<f32>, NumericDbMart) {
    let g = SyntheaConfig::small().generate_with_truth();
    let db = NumericDbMart::encode(&g.dbmart);
    let mut records = mine_sequences(&db, &MiningConfig::default()).unwrap().records;
    sparsity::screen(&mut records, &SparsityConfig { min_patients: 8, threads: 0 });
    let m = SeqMatrix::build(&records, db.num_patients() as u32).unwrap();
    let pc: std::collections::BTreeSet<&str> =
        g.truth.postcovid.iter().map(|(p, _)| p.as_str()).collect();
    let labels: Vec<f32> = (0..db.num_patients())
        .map(|p| f32::from(pc.contains(db.lookup.patient_name(p as u32))))
        .collect();
    (m, labels, db)
}

/// Label co-occurrence counts: PJRT accumulation == pure-Rust CSR scan.
#[test]
fn pjrt_label_counts_match_rust() {
    let Some(arts) = artifacts() else { return };
    let (m, labels, _) = mined_matrix();
    let rust = msmr::label_counts_rust(&m, &labels);
    let pjrt = msmr::label_counts_pjrt(&m, &labels, &arts).unwrap();
    assert_eq!(rust.len(), pjrt.len());
    for (i, (a, b)) in rust.iter().zip(&pjrt).enumerate() {
        assert!((a - b).abs() < 1e-3, "col {i}: rust {a} pjrt {b}");
    }
}

/// Pairwise co-occurrence counts over a pool: PJRT == Rust.
#[test]
fn pjrt_pair_counts_match_rust() {
    let Some(arts) = artifacts() else { return };
    let (m, _, _) = mined_matrix();
    let pool: Vec<u32> = (0..(m.num_cols() as u32).min(64)).collect();
    let rust = msmr::pair_counts_rust(&m, &pool);
    let pjrt = msmr::pair_counts_pjrt(&m, &pool, &arts).unwrap();
    for (i, (a, b)) in rust.iter().zip(&pjrt).enumerate() {
        assert!((a - b).abs() < 1e-3, "cell {i}: rust {a} pjrt {b}");
    }
}

/// Full MSMR selection must pick the same columns through both engines.
#[test]
fn msmr_selection_identical_across_engines() {
    let Some(arts) = artifacts() else { return };
    let (m, labels, _) = mined_matrix();
    let cfg = MsmrConfig { top_k: 20, pool_size: 64, beta: 1.0 };
    let rust_sel = msmr::select(&m, &labels, &cfg, None).unwrap();
    let pjrt_sel = msmr::select(&m, &labels, &cfg, Some(&arts)).unwrap();
    assert_eq!(rust_sel.columns, pjrt_sel.columns);
}

/// Full MLHO workflow through PJRT reaches the same quality as Rust.
#[test]
fn mlho_quality_parity() {
    let Some(arts) = artifacts() else { return };
    let (m, labels, _) = mined_matrix();
    let sel = msmr::select(
        &m,
        &labels,
        &MsmrConfig { top_k: 50, pool_size: 128, beta: 1.0 },
        Some(&arts),
    )
    .unwrap();
    let selected = m.select_columns(&sel.columns);
    let cfg = ml::TrainConfig { epochs: 80, ..Default::default() };
    let (_, _, rust_test) = ml::run_workflow(&selected, &labels, &cfg, None).unwrap();
    let (_, _, pjrt_test) = ml::run_workflow(&selected, &labels, &cfg, Some(&arts)).unwrap();
    assert!(
        (rust_test.auc - pjrt_test.auc).abs() < 0.02,
        "AUC diverged: rust {} vs pjrt {}",
        rust_test.auc,
        pjrt_test.auc
    );
}

/// The raw cooc artifact (Pallas kernel) on a full random tile, checked
/// cell-exactly against a Rust dot product.
#[test]
fn cooc_artifact_exact_on_dense_random()
{
    let Some(arts) = artifacts() else { return };
    let (p, f) = (arts.tile_rows, arts.tile_features);
    let mut rng = tspm_plus::rng::Rng::new(2024);
    let x: Vec<f32> = (0..p * f).map(|_| f32::from(rng.gen_bool(0.35))).collect();
    let y: Vec<f32> = (0..p * f).map(|_| f32::from(rng.gen_bool(0.15))).collect();
    let out = arts
        .get("cooc")
        .unwrap()
        .run(&[Tensor::new(vec![p, f], x.clone()), Tensor::new(vec![p, f], y.clone())])
        .unwrap();
    for probe in 0..50 {
        let a = (probe * 37) % f;
        let b = (probe * 91) % f;
        let want: f32 = (0..p).map(|r| x[r * f + a] * y[r * f + b]).sum();
        assert_eq!(out[0].data[a * f + b], want, "cell ({a},{b})");
    }
}

/// Post-COVID identification: PJRT correlation path equals Rust path.
#[test]
fn postcovid_identical_across_engines() {
    let Some(arts) = artifacts() else { return };
    use tspm_plus::postcovid::{identify, PostCovidConfig};
    use tspm_plus::synthea::{COVID_CODE, SYMPTOM_CODES};
    let g = SyntheaConfig::small().generate_with_truth();
    let db = NumericDbMart::encode(&g.dbmart);
    let mined = mine_sequences(&db, &MiningConfig::default()).unwrap();
    let covid = db.lookup.phenx_id(COVID_CODE).unwrap();
    let mut cfg = PostCovidConfig::new(covid);
    cfg.candidate_filter =
        Some(SYMPTOM_CODES.iter().filter_map(|s| db.lookup.phenx_id(s)).collect());
    let rust = identify(&mined.records, db.num_patients() as u32, &cfg, None).unwrap();
    let pjrt = identify(&mined.records, db.num_patients() as u32, &cfg, Some(&arts)).unwrap();
    assert_eq!(rust.confirmed, pjrt.confirmed);
    assert_eq!(rust.candidates, pjrt.candidates);
}
