//! Serving-layer concurrency smoke tests: N threads hammering one
//! `QueryService` (directly, and through the serve loop via `Client`)
//! must see byte-identical answers to a single-threaded run, the cache
//! counters must balance (`hits + misses == lookups`), admission
//! control must shed excess connections with a typed `busy`, artifact
//! hot-swap must never interrupt in-flight readers, and streaming
//! `by_patient` must hold block-bounded memory (MemTracker-asserted).

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use tspm_plus::metrics::MemTracker;
use tspm_plus::mining::SeqRecord;
use tspm_plus::query::{self, IndexConfig, QueryError, QueryService};
use tspm_plus::rng::Rng;
use tspm_plus::seqstore::{self, SeqFileSet, RECORD_BYTES};
use tspm_plus::serve::{Client, ErrorCode, Registry, ServeConfig, ServeError, Server};

/// Small blocks so even the fixture-sized artifacts span many of them.
const BLOCK_RECORDS: usize = 32;
const CACHE_BYTES: usize = 1 << 20;

fn tmpdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("tspm_serve_conc_{}", std::process::id()))
        .join(name);
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// A random sorted multiset shaped like a screened run.
fn random_sorted(seed: u64, n: usize, n_seqs: u64, n_pids: u64) -> Vec<SeqRecord> {
    let mut r = Rng::new(seed);
    let mut v: Vec<SeqRecord> = (0..n)
        .map(|_| SeqRecord {
            seq: r.gen_range(n_seqs),
            pid: r.gen_range(n_pids) as u32,
            duration: r.gen_range(350) as u32,
        })
        .collect();
    v.sort_unstable_by_key(|x| (x.seq, x.pid, x.duration));
    v
}

/// Spill `records` and build a v2 (pid-indexed) artifact under a fresh
/// tmpdir; returns the index directory.
fn build_artifact(name: &str, records: &[SeqRecord], num_patients: u32) -> PathBuf {
    let dir = tmpdir(name);
    let spill = dir.join("part_0.tspm");
    seqstore::write_file(&spill, records).unwrap();
    let input = SeqFileSet {
        files: vec![spill],
        total_records: records.len() as u64,
        num_patients,
        num_phenx: 0,
    };
    let out = dir.join("idx");
    query::index::build(
        &input,
        &out,
        &IndexConfig { block_records: BLOCK_RECORDS, ..Default::default() },
        None,
    )
    .unwrap();
    out
}

/// Short poll so shed-permit release and shutdown are visible quickly.
fn fast_cfg(max_conns: usize) -> ServeConfig {
    ServeConfig {
        max_conns,
        poll_interval: Duration::from_millis(5),
        idle_timeout: Duration::from_secs(10),
        ..ServeConfig::default()
    }
}

/// Probe sets exercising every query kind, including absent keys.
fn probes(records: &[SeqRecord]) -> (Vec<u64>, Vec<u32>) {
    let mut seqs: Vec<u64> = records.iter().map(|r| r.seq).collect();
    seqs.dedup();
    let stride = (seqs.len() / 10).max(1);
    let mut seq_probes: Vec<u64> = seqs.iter().step_by(stride).take(10).copied().collect();
    seq_probes.push(999_999_999); // absent
    let mut pid_probes: Vec<u32> = vec![0, 1, 2, 3, 5, 8, 13, 21, 34, 55];
    pid_probes.push(9_999); // absent
    (seq_probes, pid_probes)
}

// ---------------------------------------------------------------------------
// 1. one shared QueryService under thread contention
// ---------------------------------------------------------------------------

#[test]
fn shared_service_answers_match_single_threaded_and_counters_balance() {
    const THREADS: usize = 8;
    let records = random_sorted(7, 6_000, 48, 64);
    let dir = build_artifact("svc_contention", &records, 64);
    let svc = Arc::new(QueryService::open_with_cache(&dir, CACHE_BYTES).unwrap());

    // Single-threaded baseline from an *independent* service over the
    // same artifact, so the contended instance's cache can't leak into
    // the expected answers.
    let base = QueryService::open_with_cache(&dir, CACHE_BYTES).unwrap();
    let (seq_probes, pid_probes) = probes(&records);
    let exp_seq: Vec<Vec<SeqRecord>> =
        seq_probes.iter().map(|&s| (*base.by_sequence(s).unwrap()).clone()).collect();
    let exp_pw: Vec<Vec<u32>> = seq_probes
        .iter()
        .map(|&s| (*base.patients_with(s, 0, 350).unwrap()).clone())
        .collect();
    let exp_hist: Vec<_> = seq_probes
        .iter()
        .map(|&s| (*base.duration_histogram(s, 8).unwrap()).clone())
        .collect();
    let exp_pid: Vec<Vec<SeqRecord>> =
        pid_probes.iter().map(|&p| (*base.by_patient(p).unwrap()).clone()).collect();
    let exp_topk = (*base.top_k_by_support(10).unwrap()).clone();

    svc.reset_stats();
    std::thread::scope(|scope| {
        for t in 0..THREADS {
            let svc = Arc::clone(&svc);
            let (seq_probes, pid_probes) = (&seq_probes, &pid_probes);
            let (exp_seq, exp_pw, exp_hist, exp_pid, exp_topk) =
                (&exp_seq, &exp_pw, &exp_hist, &exp_pid, &exp_topk);
            scope.spawn(move || {
                // Each thread walks the probes in a different rotation so
                // the cache sees genuinely interleaved access patterns.
                for i in 0..seq_probes.len() {
                    let j = (i + t) % seq_probes.len();
                    let s = seq_probes[j];
                    assert_eq!(*svc.by_sequence(s).unwrap(), exp_seq[j], "seq {s}");
                    assert_eq!(*svc.patients_with(s, 0, 350).unwrap(), exp_pw[j]);
                    assert_eq!(*svc.duration_histogram(s, 8).unwrap(), exp_hist[j]);
                }
                for i in 0..pid_probes.len() {
                    let j = (i + t) % pid_probes.len();
                    let p = pid_probes[j];
                    assert_eq!(*svc.by_patient(p).unwrap(), exp_pid[j], "pid {p}");
                    // The uncached streaming path must agree chunk-for-chunk.
                    let mut streamed = Vec::new();
                    let total = svc
                        .by_patient_visit::<QueryError>(p, |chunk| {
                            assert!(chunk.len() <= BLOCK_RECORDS);
                            streamed.extend_from_slice(chunk);
                            Ok(())
                        })
                        .unwrap();
                    assert_eq!(streamed, exp_pid[j]);
                    assert_eq!(total as usize, exp_pid[j].len());
                }
                assert_eq!(*svc.top_k_by_support(10).unwrap(), *exp_topk);
            });
        }
    });

    // Every cacheable call either hit or missed — nothing torn, nothing
    // double-counted. (by_patient_visit bypasses the cache by contract.)
    let lookups = (THREADS * (3 * seq_probes.len() + pid_probes.len() + 1)) as u64;
    let st = svc.stats();
    assert_eq!(st.hits + st.misses, lookups, "stats: {st:?}");
    assert!(st.misses >= (3 * seq_probes.len() + pid_probes.len() + 1) as u64);
}

// ---------------------------------------------------------------------------
// 2. the server loop under concurrent clients, ending in graceful drain
// ---------------------------------------------------------------------------

#[test]
fn concurrent_clients_get_single_threaded_answers_and_server_drains() {
    const CLIENTS: usize = 6;
    let records = random_sorted(11, 5_000, 40, 64);
    let dir = build_artifact("srv_clients", &records, 64);
    let direct = QueryService::open_with_cache(&dir, CACHE_BYTES).unwrap();
    let (seq_probes, pid_probes) = probes(&records);

    let registry = Arc::new(Registry::new(CACHE_BYTES));
    registry.open_and_register("idx", &dir).unwrap();
    let server = Server::bind("127.0.0.1:0", registry, fast_cfg(16)).unwrap();
    let addr = server.local_addr().to_string();
    let (handle, join) = server.spawn();

    std::thread::scope(|scope| {
        for t in 0..CLIENTS {
            let (addr, direct) = (&addr, &direct);
            let (seq_probes, pid_probes) = (&seq_probes, &pid_probes);
            scope.spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                for i in 0..seq_probes.len() {
                    let s = seq_probes[(i + t) % seq_probes.len()];
                    let want = direct.by_sequence(s).unwrap();
                    let (got, total) = c.by_sequence(None, s, None).unwrap();
                    assert_eq!(got, *want, "seq {s}");
                    assert_eq!(total as usize, want.len());
                    // A limit truncates the page but reports the full total.
                    let (page, lim_total) = c.by_sequence(None, s, Some(3)).unwrap();
                    assert_eq!(page, want[..want.len().min(3)]);
                    assert_eq!(lim_total as usize, want.len());
                    let want_pw = direct.patients_with(s, 0, 350).unwrap();
                    let (pw, pw_total) = c.patients_with(None, s, 0, 350, None).unwrap();
                    assert_eq!(pw, *want_pw);
                    assert_eq!(pw_total as usize, want_pw.len());
                    assert_eq!(
                        c.histogram(None, s, 8).unwrap(),
                        *direct.duration_histogram(s, 8).unwrap()
                    );
                }
                for i in 0..pid_probes.len() {
                    let p = pid_probes[(i + t) % pid_probes.len()];
                    let want = direct.by_patient(p).unwrap();
                    let mut streamed = Vec::new();
                    let total = c
                        .by_patient_visit(None, p, |chunk| {
                            assert!(chunk.len() <= BLOCK_RECORDS);
                            streamed.extend_from_slice(chunk);
                        })
                        .unwrap();
                    assert_eq!(streamed, *want, "pid {p}");
                    assert_eq!(total as usize, want.len());
                }
                assert_eq!(c.top_k(None, 10).unwrap(), *direct.top_k_by_support(10).unwrap());
            });
        }
    });

    handle.shutdown();
    let summary = join.join().unwrap().expect("server drains cleanly");
    assert_eq!(summary.shed, 0, "no client should have been shed: {summary:?}");
    assert!(summary.served >= CLIENTS as u64, "summary: {summary:?}");
    assert!(summary.requests > 0);
}

// ---------------------------------------------------------------------------
// 3. admission control: excess connections get a typed busy
// ---------------------------------------------------------------------------

#[test]
fn excess_connections_are_shed_with_typed_busy() {
    let records = random_sorted(3, 400, 8, 8);
    let dir = build_artifact("srv_busy", &records, 8);
    let registry = Arc::new(Registry::new(CACHE_BYTES));
    registry.open_and_register("idx", &dir).unwrap();
    let server = Server::bind("127.0.0.1:0", registry, fast_cfg(1)).unwrap();
    let addr = server.local_addr().to_string();
    let (handle, join) = server.spawn();

    // The sole permit goes to the first client…
    let mut holder = Client::connect(&addr).unwrap();
    holder.ping().unwrap();
    // …so the second is shed — a typed Busy, never a hang or a raw
    // connection reset.
    let mut shed = Client::connect(&addr).unwrap();
    match shed.ping() {
        Err(ServeError::Busy) => {}
        other => panic!("expected Busy, got {other:?}"),
    }
    drop(shed);

    // Releasing the holder frees the permit (the handler notices the
    // EOF within one poll interval); new clients are admitted again.
    drop(holder);
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        let mut c = Client::connect(&addr).unwrap();
        match c.ping() {
            Ok(()) => break,
            Err(ServeError::Busy) => {
                assert!(Instant::now() < deadline, "permit never released");
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) => panic!("unexpected error: {e}"),
        }
    }

    handle.shutdown();
    let summary = join.join().unwrap().unwrap();
    assert!(summary.shed >= 1, "shed counter must record the busy: {summary:?}");
}

// ---------------------------------------------------------------------------
// 4. hot-swap: retire/register mid-run never drops a connection
// ---------------------------------------------------------------------------

#[test]
fn hot_swap_yields_typed_not_found_and_never_drops_connections() {
    let rec_a = random_sorted(21, 2_000, 24, 32);
    let rec_b = random_sorted(22, 2_000, 24, 32);
    let dir_a = build_artifact("swap_a", &rec_a, 32);
    let dir_b = build_artifact("swap_b", &rec_b, 32);
    let registry = Arc::new(Registry::new(CACHE_BYTES));
    registry.open_and_register("a", &dir_a).unwrap();
    registry.open_and_register("b", &dir_b).unwrap();
    let server = Server::bind("127.0.0.1:0", registry, fast_cfg(8)).unwrap();
    let addr = server.local_addr().to_string();
    let (handle, join) = server.spawn();

    let probe_a = rec_a[rec_a.len() / 2].seq;
    let probe_b = rec_b[rec_b.len() / 2].seq;
    let mut ops = Client::connect(&addr).unwrap();
    let mut rdr = Client::connect(&addr).unwrap();
    let before = rdr.by_sequence(Some("b"), probe_b, None).unwrap();
    assert!(!before.0.is_empty());

    // Retire "b" on one connection; the reader's connection survives
    // and gets a *typed* not_found naming the artifact — not a drop.
    ops.retire("b").unwrap();
    match rdr.by_sequence(Some("b"), probe_b, None) {
        Err(ServeError::Remote { code: ErrorCode::NotFound, message }) => {
            assert!(message.contains('b'), "message should name the id: {message}");
        }
        other => panic!("expected typed not_found, got {other:?}"),
    }
    rdr.ping().unwrap(); // same connection, still alive
    assert!(!rdr.by_sequence(Some("a"), probe_a, None).unwrap().0.is_empty());

    // Register it back over the wire: answers return byte-identically.
    ops.register("b", dir_b.to_str().unwrap()).unwrap();
    assert_eq!(rdr.by_sequence(Some("b"), probe_b, None).unwrap(), before);

    // Thrash the swap while a reader hammers "b": every answer is
    // either the full correct one or a typed not_found — never an IO
    // error, never a truncated record set.
    std::thread::scope(|scope| {
        let addr = &addr;
        let swapper = scope.spawn(move || {
            let mut c = Client::connect(addr).unwrap();
            for _ in 0..20 {
                c.retire("b").unwrap();
                c.register("b", dir_b.to_str().unwrap()).unwrap();
            }
        });
        let expected = &before.0;
        scope.spawn(move || {
            let mut c = Client::connect(addr).unwrap();
            let mut ok = 0u32;
            let mut missed = 0u32;
            for _ in 0..200 {
                match c.by_sequence(Some("b"), probe_b, None) {
                    Ok((recs, _)) => {
                        assert_eq!(recs, *expected);
                        ok += 1;
                    }
                    Err(ServeError::Remote { code: ErrorCode::NotFound, .. }) => missed += 1,
                    Err(e) => panic!("hot-swap broke a reader: {e}"),
                }
            }
            assert_eq!(ok + missed, 200);
            assert!(ok > 0, "reader never saw the artifact");
        });
        swapper.join().unwrap();
    });

    // Retiring an unknown id is typed, too.
    match ops.retire("ghost") {
        Err(ServeError::Remote { code: ErrorCode::NotFound, .. }) => {}
        other => panic!("expected not_found, got {other:?}"),
    }

    handle.shutdown();
    join.join().unwrap().unwrap();
}

// ---------------------------------------------------------------------------
// 5. streaming by_patient holds block-bounded memory
// ---------------------------------------------------------------------------

#[test]
fn streaming_by_patient_memory_is_bounded_by_block_size() {
    // One deliberately heavy patient: 4096 records across 256 sequences,
    // 128× the block size — a buffered answer would hold all of it.
    const HEAVY_PID: u32 = 3;
    let mut records: Vec<SeqRecord> = Vec::new();
    for s in 0..256u64 {
        for k in 0..16u32 {
            records.push(SeqRecord { seq: s, pid: HEAVY_PID, duration: k });
        }
        // A little background noise from other patients (pids 0..3,
        // never the heavy one).
        records.push(SeqRecord { seq: s, pid: (s % 3) as u32, duration: 1 });
    }
    records.sort_unstable_by_key(|x| (x.seq, x.pid, x.duration));
    let dir = build_artifact("heavy_patient", &records, 8);

    let mut svc = QueryService::open_with_cache(&dir, CACHE_BYTES).unwrap();
    let tracker = Arc::new(MemTracker::new());
    svc.set_tracker(Arc::clone(&tracker));

    let mut streamed: Vec<SeqRecord> = Vec::new();
    let mut max_chunk = 0usize;
    let total = svc
        .by_patient_visit::<QueryError>(HEAVY_PID, |chunk| {
            max_chunk = max_chunk.max(chunk.len());
            streamed.extend_from_slice(chunk);
            Ok(())
        })
        .unwrap();

    let block_bytes = (BLOCK_RECORDS * RECORD_BYTES) as u64;
    let patient_bytes = total * RECORD_BYTES as u64;
    assert_eq!(total, 4096);
    assert!(max_chunk <= BLOCK_RECORDS);
    // The contract under test: the v2 streaming path holds the two
    // shared scan buffers — nothing proportional to the patient.
    assert!(
        tracker.peak() <= 2 * block_bytes,
        "peak {} exceeds two blocks ({})",
        tracker.peak(),
        2 * block_bytes
    );
    assert!(
        patient_bytes >= 64 * block_bytes,
        "fixture too small to prove anything: {patient_bytes} vs {block_bytes}"
    );

    // And the stream is byte-identical to the buffered answer.
    assert_eq!(streamed, *svc.by_patient(HEAVY_PID).unwrap());
}
