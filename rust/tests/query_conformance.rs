//! Query-subsystem conformance: every `QueryService` answer must equal
//! the brute-force scan over `SeqFileSet::read_all()`, identically
//! across block sizes and with the cache on or off; working memory must
//! stay block-bounded; and the engine's `.index(dir)` stage must yield
//! an artifact whose answers match the spilled run exactly.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use tspm_plus::dbmart::NumericDbMart;
use tspm_plus::engine::{Engine, OutputKind};
use tspm_plus::matrix::SeqMatrix;
use tspm_plus::metrics::MemTracker;
use tspm_plus::mining::{MiningConfig, SeqRecord};
use tspm_plus::query::{self, IndexConfig, QueryService, SeqIndex, SeqSupport};
use tspm_plus::rng::Rng;
use tspm_plus::seqstore::{self, SeqFileSet, RECORD_BYTES};
use tspm_plus::sparsity::SparsityConfig;
use tspm_plus::synthea::SyntheaConfig;

fn tmpdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("tspm_query_conf_{}", std::process::id()))
        .join(name);
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Write `records` (already globally sorted) as an n-file spill set.
fn spill(dir: &Path, records: &[SeqRecord], n_files: usize, num_patients: u32) -> SeqFileSet {
    let chunk = records.len().div_ceil(n_files.max(1)).max(1);
    let mut files = Vec::new();
    for (i, part) in records.chunks(chunk).enumerate() {
        let p = dir.join(format!("part_{i}.tspm"));
        seqstore::write_file(&p, part).unwrap();
        files.push(p);
    }
    if files.is_empty() {
        let p = dir.join("part_0.tspm");
        seqstore::write_file(&p, &[]).unwrap();
        files.push(p);
    }
    SeqFileSet { files, total_records: records.len() as u64, num_patients, num_phenx: 0 }
}

/// A random sorted multiset shaped like a screened run.
fn random_sorted(case: u64, n: usize, n_seqs: u64, n_pats: u64) -> Vec<SeqRecord> {
    let mut r = Rng::new(case);
    let mut v: Vec<SeqRecord> = (0..n)
        .map(|_| SeqRecord {
            seq: r.gen_range(n_seqs),
            pid: r.gen_range(n_pats) as u32,
            duration: r.gen_range(700) as u32,
        })
        .collect();
    v.sort_unstable_by_key(|x| (x.seq, x.pid, x.duration));
    v
}

fn brute_by_seq(all: &[SeqRecord], seq: u64) -> Vec<SeqRecord> {
    all.iter().copied().filter(|r| r.seq == seq).collect()
}

fn brute_by_pid(all: &[SeqRecord], pid: u32) -> Vec<SeqRecord> {
    all.iter().copied().filter(|r| r.pid == pid).collect()
}

fn brute_patients_with(all: &[SeqRecord], seq: u64, lo: u32, hi: u32) -> Vec<u32> {
    let mut v: Vec<u32> = all
        .iter()
        .filter(|r| r.seq == seq && (lo..=hi).contains(&r.duration))
        .map(|r| r.pid)
        .collect();
    v.sort_unstable();
    v.dedup();
    v
}

fn brute_top_k(all: &[SeqRecord], k: usize) -> Vec<SeqSupport> {
    let mut rows: Vec<SeqSupport> = Vec::new();
    let mut i = 0;
    while i < all.len() {
        let seq = all[i].seq;
        let mut j = i;
        let mut patients = 0u32;
        let mut last_pid = None;
        while j < all.len() && all[j].seq == seq {
            if last_pid != Some(all[j].pid) {
                patients += 1;
                last_pid = Some(all[j].pid);
            }
            j += 1;
        }
        rows.push(SeqSupport { seq, patients, records: (j - i) as u64 });
        i = j;
    }
    rows.sort_unstable_by(|a, b| b.patients.cmp(&a.patients).then(a.seq.cmp(&b.seq)));
    rows.truncate(k.min(rows.len()));
    rows
}

/// The core property: every answer equals the brute-force scan, for
/// every block size, with the cache on and off — and the cached and
/// uncached services agree with each other by construction.
#[test]
fn answers_equal_brute_force_across_block_sizes_and_cache_settings() {
    let mut meta = Rng::new(0xBEEF);
    for case in 0..4u64 {
        let n = 2_000 + meta.gen_range(8_000) as usize;
        let n_seqs = 1 + meta.gen_range(60);
        let n_pats = 1 + meta.gen_range(50);
        let all = random_sorted(case + 1, n, n_seqs, n_pats);
        let dir = tmpdir(&format!("prop_{case}"));
        let input = spill(&dir, &all, 3, n_pats as u32);

        // Sample sequences: present (first/middle/last) and absent.
        let mut sample_seqs: Vec<u64> =
            vec![all[0].seq, all[all.len() / 2].seq, all[all.len() - 1].seq, u64::MAX];
        sample_seqs.dedup();
        let sample_pids = [0u32, (n_pats / 2) as u32, u32::MAX];

        for &block in &[7usize, 128, 4096] {
            let idx_dir = dir.join(format!("idx_{block}"));
            query::index::build(
                &input,
                &idx_dir,
                &IndexConfig { block_records: block, ..Default::default() },
                None,
            )
            .unwrap();
            for &cache_bytes in &[0usize, 1 << 20] {
                let svc = QueryService::open_with_cache(&idx_dir, cache_bytes).unwrap();
                let ctx = format!("case={case} block={block} cache={cache_bytes}");
                for &s in &sample_seqs {
                    assert_eq!(*svc.by_sequence(s).unwrap(), brute_by_seq(&all, s), "{ctx}");
                    assert_eq!(
                        *svc.patients_with(s, 100, 400).unwrap(),
                        brute_patients_with(&all, s, 100, 400),
                        "{ctx}"
                    );
                    let h = svc.duration_histogram(s, 6).unwrap();
                    let expect = brute_by_seq(&all, s);
                    assert_eq!(h.total, expect.len() as u64, "{ctx}");
                    assert_eq!(
                        h.buckets.iter().map(|b| b.count).sum::<u64>(),
                        expect.len() as u64,
                        "{ctx}"
                    );
                    for b in &h.buckets {
                        let want = expect
                            .iter()
                            .filter(|r| (b.lo..=b.hi).contains(&r.duration))
                            .count() as u64;
                        assert_eq!(b.count, want, "{ctx} bucket {}..={}", b.lo, b.hi);
                    }
                }
                for &p in &sample_pids {
                    assert_eq!(*svc.by_patient(p).unwrap(), brute_by_pid(&all, p), "{ctx}");
                }
                for &k in &[1usize, 5, usize::MAX] {
                    assert_eq!(*svc.top_k_by_support(k).unwrap(), brute_top_k(&all, k), "{ctx}");
                }
                // Asking again (cache warm or recomputed) changes nothing.
                let s = sample_seqs[0];
                assert_eq!(*svc.by_sequence(s).unwrap(), brute_by_seq(&all, s), "{ctx}");
                if cache_bytes > 0 {
                    assert!(svc.stats().hits > 0, "{ctx}: repeat must hit the cache");
                } else {
                    assert_eq!(svc.stats().hits, 0, "{ctx}: cache disabled");
                }
            }
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// Acceptance: the service's working memory stays bounded by the block
/// size, not the data size — proved with a MemTracker on a dataset two
/// orders of magnitude larger than a block.
#[test]
fn query_memory_is_bounded_by_block_size_not_data_size() {
    let all = random_sorted(7, 60_000, 40, 300);
    let data_bytes = (all.len() * RECORD_BYTES) as u64;
    let dir = tmpdir("bounded");
    let input = spill(&dir, &all, 1, 300);
    let block = 256usize;
    let idx_dir = dir.join("idx");
    query::index::build(
        &input,
        &idx_dir,
        &IndexConfig { block_records: block, ..Default::default() },
        None,
    )
    .unwrap();

    let mut svc = QueryService::open_with_cache(&idx_dir, 0).unwrap();
    let tracker = Arc::new(MemTracker::new());
    svc.set_tracker(tracker.clone());

    let heavy = svc.top_k_by_support(1).unwrap()[0].seq;
    assert!(!svc.by_sequence(heavy).unwrap().is_empty());
    assert!(!svc.by_patient(all[0].pid).unwrap().is_empty());
    svc.patients_with(heavy, 0, u32::MAX).unwrap();
    svc.duration_histogram(heavy, 16).unwrap();

    // One record buffer + one reader buffer per scan: 2 × block × 16 B.
    let bound = 2 * (block * RECORD_BYTES) as u64;
    assert!(
        tracker.peak() <= bound,
        "peak {} exceeds the block bound {bound}",
        tracker.peak()
    );
    assert!(
        tracker.peak() * 50 < data_bytes,
        "peak {} is not far below the {data_bytes}-byte data set",
        tracker.peak()
    );
    assert_eq!(tracker.live(), 0, "all query buffers released");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Acceptance: mine → screen (spilled) → index through the engine, then
/// `QueryService` answers exactly what a full materialized scan yields,
/// and repeated queries hit the LRU cache.
#[test]
fn engine_chain_mine_screen_index_query_round_trip() {
    let db = NumericDbMart::encode(&SyntheaConfig::small().generate());
    let base = tmpdir("engine_chain");
    let out = Engine::from_dbmart(db)
        .mine(MiningConfig { work_dir: base.join("work"), ..Default::default() })
        .screen(SparsityConfig { min_patients: 5, threads: 2 })
        .out_dir(base.join("run"))
        .index_with(base.join("idx"), 512)
        .run()
        .unwrap();
    assert_eq!(out.report.output, OutputKind::Spilled);
    let built = out.index.as_ref().expect("index stage ran");
    assert_eq!(built.block_records, 512);

    // Full materialized scan = the reference answer set.
    let all = out.sequences.clone().materialize().unwrap().records;
    assert_eq!(built.total_records, all.len() as u64);

    let svc = QueryService::open(&base.join("idx")).unwrap();
    let mut seqs: Vec<u64> = all.iter().map(|r| r.seq).collect();
    seqs.dedup();
    assert_eq!(svc.index().distinct_seqs(), seqs.len() as u64);
    for &s in seqs.iter().take(25) {
        assert_eq!(*svc.by_sequence(s).unwrap(), brute_by_seq(&all, s), "seq {s}");
    }
    assert_eq!(*svc.top_k_by_support(10).unwrap(), brute_top_k(&all, 10));

    // Repeating the same query is a cache hit sharing the same Arc.
    let s = seqs[0];
    let first = svc.by_sequence(s).unwrap();
    let again = svc.by_sequence(s).unwrap();
    assert!(Arc::ptr_eq(&first, &again));
    assert!(svc.stats().hits >= 1, "stats: {:?}", svc.stats());
    let _ = std::fs::remove_dir_all(&base);
}

/// Tentpole property: `SeqMatrix::from_index` equals `SeqMatrix::build`
/// on the materialized records — **all four CSR fields** — across block
/// sizes and in both column spaces, on random dbmart shapes; and its
/// working memory stays O(block + output CSR), MemTracker-proven.
#[test]
fn from_index_matrix_equals_build_across_block_sizes() {
    let mut meta = Rng::new(0xC0FFEE);
    for case in 0..3u64 {
        let n = 1_000 + meta.gen_range(6_000) as usize;
        let n_seqs = 1 + meta.gen_range(50);
        let n_pats = 1 + meta.gen_range(40) as u32;
        let all = random_sorted(100 + case, n, n_seqs, n_pats as u64);
        let dir = tmpdir(&format!("matrix_prop_{case}"));
        let input = spill(&dir, &all, 2, n_pats);
        let direct = SeqMatrix::build(&all, n_pats).unwrap();
        let direct_dur = SeqMatrix::build_with_durations(&all, n_pats, 30).unwrap();
        for &block in &[7usize, 128, 4096] {
            let idx_dir = dir.join(format!("idx_{block}"));
            let idx = query::index::build(
                &input,
                &idx_dir,
                &IndexConfig { block_records: block, ..Default::default() },
                None,
            )
            .unwrap();
            let tracker = MemTracker::new();
            let streamed =
                SeqMatrix::from_index_tracked(&idx, n_pats, None, Some(&tracker)).unwrap();
            assert_eq!(streamed.seq_ids, direct.seq_ids, "case={case} block={block}");
            assert_eq!(streamed.row_ptr, direct.row_ptr, "case={case} block={block}");
            assert_eq!(streamed.col_idx, direct.col_idx, "case={case} block={block}");
            assert_eq!(streamed.num_patients, direct.num_patients);
            assert_eq!(tracker.live(), 0, "all matrix buffers released");
            // O(block + output CSR): one read buffer plus the CSR arrays
            // and their same-order temporaries — never the record set.
            let cap = block.clamp(1, 64 * 1024) as u64;
            let (rows, cols, nnz) =
                (n_pats as u64, direct.seq_ids.len() as u64, direct.nnz() as u64);
            let bound = 16 * cap + 24 * rows + 8 * cols + 12 * nnz + 64;
            assert!(
                tracker.peak() <= bound,
                "case={case} block={block}: peak {} > bound {bound}",
                tracker.peak()
            );
            let streamed_dur =
                SeqMatrix::from_index_tracked(&idx, n_pats, Some(30), None).unwrap();
            assert_eq!(streamed_dur, direct_dur, "case={case} block={block} durations");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// Tentpole property: the pid-indexed `by_patient` fast path returns
/// byte-identical records to the v1 scan path — and to a v1 artifact's
/// answers — on random dbmarts.
#[test]
fn by_patient_fast_path_matches_v1_scan_on_random_dbmarts() {
    let mut meta = Rng::new(0xFEED);
    for case in 0..3u64 {
        let n = 1_000 + meta.gen_range(5_000) as usize;
        let n_pats = 1 + meta.gen_range(60);
        let all = random_sorted(200 + case, n, 1 + meta.gen_range(40), n_pats);
        let dir = tmpdir(&format!("pid_prop_{case}"));
        let input = spill(&dir, &all, 2, n_pats as u32);
        let v2_dir = dir.join("idx_v2");
        let v1_dir = dir.join("idx_v1");
        query::index::build(&input, &v2_dir, &IndexConfig::default(), None).unwrap();
        query::index::build(
            &input,
            &v1_dir,
            &IndexConfig { pid_index: false, ..Default::default() },
            None,
        )
        .unwrap();
        let v2 = QueryService::open_with_cache(&v2_dir, 0).unwrap();
        let v1 = QueryService::open_with_cache(&v1_dir, 0).unwrap();
        assert!(v2.index().pids.is_some() && v1.index().pids.is_none());
        for pid in (0..n_pats as u32).chain([n_pats as u32 + 7, u32::MAX]) {
            let expect = brute_by_pid(&all, pid);
            assert_eq!(*v2.by_patient(pid).unwrap(), expect, "case={case} pid={pid} fast");
            assert_eq!(
                v2.by_patient_scan(pid).unwrap(),
                expect,
                "case={case} pid={pid} scan"
            );
            assert_eq!(*v1.by_patient(pid).unwrap(), expect, "case={case} pid={pid} v1");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// Acceptance: `by_patient` no longer scans the data file — the bytes
/// read scale with the patient's own records, not the artifact size.
#[test]
fn by_patient_io_scales_with_the_answer_not_the_artifact() {
    let all = random_sorted(31, 60_000, 50, 400);
    let dir = tmpdir("pid_io");
    let input = spill(&dir, &all, 1, 400);
    let idx_dir = dir.join("idx");
    query::index::build(&input, &idx_dir, &IndexConfig::default(), None).unwrap();
    let svc = QueryService::open_with_cache(&idx_dir, 0).unwrap();
    let artifact_record_bytes = (all.len() * RECORD_BYTES) as u64;

    let pid = all[all.len() / 2].pid;
    let expect = brute_by_pid(&all, pid);
    let before = svc.stats().logical_bytes_read;
    let got = svc.by_patient(pid).unwrap();
    let fast_bytes = svc.stats().logical_bytes_read - before;
    assert_eq!(*got, expect);
    // Exactly the patient's own records are streamed — nothing else.
    assert_eq!(fast_bytes, expect.len() as u64 * RECORD_BYTES as u64);
    assert!(
        fast_bytes * 50 < artifact_record_bytes,
        "fast path read {fast_bytes} of {artifact_record_bytes} bytes"
    );
    // The v1 scan path on the same artifact reads the bulk of the file
    // (random pids appear in nearly every block) — the gap is the win.
    let before = svc.stats().logical_bytes_read;
    assert_eq!(svc.by_patient_scan(pid).unwrap(), expect);
    let scan_bytes = svc.stats().logical_bytes_read - before;
    assert!(
        scan_bytes > fast_bytes * 10,
        "scan {scan_bytes} vs fast {fast_bytes}: the pid index must change the IO class"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// Acceptance: the full out-of-core chain — mine → screen → index →
/// matrix → msmr — completes under a memory budget far below the
/// materialized record multiset, with CSR output bit-identical to the
/// in-memory path.
#[test]
fn engine_out_of_core_chain_stays_under_budget() {
    let db = NumericDbMart::encode(&SyntheaConfig::small().generate());
    let labels: Vec<f32> = (0..db.num_patients()).map(|p| f32::from(p % 4 == 0)).collect();
    let base = tmpdir("ooc_chain");

    let golden = Engine::from_dbmart(db.clone())
        .mine(MiningConfig { work_dir: base.join("mem"), ..Default::default() })
        .screen(SparsityConfig { min_patients: 5, threads: 2 })
        .matrix()
        .msmr(25)
        .labels(labels.clone())
        .run()
        .unwrap();

    let budget: u64 = std::env::var("TSPM_MEMORY_BUDGET")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1 << 20);
    let spilled = Engine::from_dbmart(db)
        .mine(MiningConfig { work_dir: base.join("spill"), ..Default::default() })
        .screen(SparsityConfig { min_patients: 5, threads: 2 })
        .out_dir(base.join("run"))
        .index_with(base.join("idx"), 512)
        .matrix()
        .msmr(25)
        .labels(labels)
        .memory_budget(budget)
        .run()
        .unwrap();

    assert_eq!(spilled.report.output, OutputKind::Spilled);
    assert_eq!(spilled.matrix.as_ref().unwrap(), golden.matrix.as_ref().unwrap());
    assert_eq!(
        spilled.selection.as_ref().unwrap().columns,
        golden.selection.as_ref().unwrap().columns
    );
    // The chain never materialised the record multiset: its tracked peak
    // stays far below the mined payload the in-memory path holds
    // resident (the forecast is that payload's exact size).
    let mined_bytes = spilled.report.forecast.total_bytes;
    assert!(
        spilled.report.peak_logical_bytes * 2 < mined_bytes,
        "peak {} is not far below the {mined_bytes}-byte mined multiset",
        spilled.report.peak_logical_bytes
    );
    assert!(
        spilled.report.peak_logical_bytes < golden.report.peak_logical_bytes,
        "the out-of-core chain must beat the in-memory chain's peak ({} vs {})",
        spilled.report.peak_logical_bytes,
        golden.report.peak_logical_bytes
    );
    let _ = std::fs::remove_dir_all(&base);
}

/// The artifact is self-contained: the spilled inputs can disappear
/// after the build and every query still answers. Reopening via
/// `SeqIndex::open` equals the just-built tables.
#[test]
fn artifact_is_self_contained_and_reopenable() {
    let all = random_sorted(21, 5_000, 30, 40);
    let dir = tmpdir("selfcontained");
    let input = spill(&dir, &all, 2, 40);
    let idx_dir = dir.join("idx");
    let built =
        query::index::build(
            &input,
            &idx_dir,
            &IndexConfig { block_records: 64, ..Default::default() },
            None,
        )
        .unwrap();
    for f in &input.files {
        std::fs::remove_file(f).unwrap();
    }
    let reopened = SeqIndex::open(&idx_dir).unwrap();
    assert_eq!(reopened.blocks, built.blocks);
    assert_eq!(reopened.seqs, built.seqs);
    reopened.verify_data().unwrap();
    let svc = QueryService::from_index(reopened, 1 << 20);
    let s = all[0].seq;
    assert_eq!(*svc.by_sequence(s).unwrap(), brute_by_seq(&all, s));
    let _ = std::fs::remove_dir_all(&dir);
}
