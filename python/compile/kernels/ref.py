"""Pure-jnp oracles for every kernel / L2 computation.

These are the correctness references: straightforward, unfused jnp
implementations that pytest (and hypothesis) compares the Pallas kernel
and the L2 model functions against.
"""

import jax.numpy as jnp

EPS = 1e-12


def cooc_ref(x, y):
    """Co-occurrence counts: xᵀ @ y."""
    return jnp.asarray(x, jnp.float32).T @ jnp.asarray(y, jnp.float32)


def mi_pair_ref(n11, ci, cj, n):
    """Pairwise mutual information between binary variables i and j.

    Args:
      n11: f32[A, B] joint positive counts.
      ci:  f32[A, 1] positive counts of the row variables.
      cj:  f32[1, B] positive counts of the column variables.
      n:   scalar total observation count.

    Returns:
      f32[A, B] MI in nats, from the 2×2 contingency table
      (n11, n10, n01, n00) with the convention 0·log(0/·) = 0.
    """
    n = jnp.asarray(n, jnp.float32)
    n11 = jnp.asarray(n11, jnp.float32)
    n10 = ci - n11
    n01 = cj - n11
    n00 = n - ci - cj + n11

    def term(nab, pa_count, pb_count):
        p = nab / n
        denom = (pa_count / n) * (pb_count / n)
        return jnp.where(nab > 0, p * jnp.log((nab / n + EPS) / (denom + EPS)), 0.0)

    mi = (
        term(n11, ci, cj)
        + term(n10, ci, n - cj)
        + term(n01, n - ci, cj)
        + term(n00, n - ci, n - cj)
    )
    return jnp.maximum(mi, 0.0)


def logreg_grad_ref(w, b, x, y, mask):
    """Full-batch logistic-regression gradient and masked mean loss.

    Args:
      w: f32[F, 1], b: f32[1, 1], x: f32[P, F], y/mask: f32[P, 1].

    Returns:
      (grad_w f32[F,1], grad_b f32[1,1], loss f32[1,1]); gradients are
      *sums* over valid rows (the Rust optimizer divides by the global
      count when accumulating across tiles), loss is the masked sum.
    """
    logits = x @ w + b
    p = 1.0 / (1.0 + jnp.exp(-logits))
    err = (p - y) * mask
    grad_w = x.T @ err
    grad_b = jnp.sum(err, keepdims=True).reshape(1, 1)
    # numerically-stable BCE: log(1+exp(-|z|)) + max(z,0) - z*y
    z = logits
    loss_vec = jnp.maximum(z, 0.0) - z * y + jnp.log1p(jnp.exp(-jnp.abs(z)))
    loss = jnp.sum(loss_vec * mask, keepdims=True).reshape(1, 1)
    return grad_w, grad_b, loss


def logreg_predict_ref(w, b, x):
    """Predicted probabilities f32[P, 1]."""
    return 1.0 / (1.0 + jnp.exp(-(x @ w + b)))


def corr_masked_ref(x, t, mask):
    """Masked Pearson correlation of every column of x with target t.

    Args:
      x: f32[P, F], t: f32[P, 1], mask: f32[P, 1] (1 = valid row).

    Returns:
      f32[F, 1] correlation per column (0 where either side is constant).
    """
    m = mask
    n = jnp.maximum(jnp.sum(m), 1.0)
    xm = jnp.sum(x * m, axis=0, keepdims=True) / n          # [1, F]
    tm = jnp.sum(t * m) / n                                  # scalar
    xc = (x - xm) * m
    tc = (t - tm) * m
    cov = xc.T @ tc                                          # [F, 1]
    varx = jnp.sum(xc * xc, axis=0, keepdims=True).T         # [F, 1]
    vart = jnp.sum(tc * tc)                                  # scalar
    denom = jnp.sqrt(varx * vart)
    return jnp.where(denom > EPS, cov / (denom + EPS), 0.0)
