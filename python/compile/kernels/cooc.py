"""L1 — Pallas co-occurrence kernel.

The dense hot-spot of the analytics layer is the binary co-occurrence
count matrix ``C = Xᵀ·Y`` over patient×feature indicator matrices: MSMR's
joint-mutual-information scoring needs all pairwise co-occurrence counts
(an F×F matmul over the patient dimension), and the Post-COVID correlation
step needs the same contraction against a target vector.

The kernel is a classic tiled matmul specialised for this contraction:

* grid ``(A/TA, B/TB, P/TP)`` — output tiles × reduction steps;
* ``X`` block ``(TP, TA)`` indexed ``(k, i)``, ``Y`` block ``(TP, TB)``
  indexed ``(k, j)`` — BlockSpec expresses the HBM↔VMEM schedule;
* an output tile accumulates across the ``k`` (patient) steps in place,
  initialised on the first step (revisiting grid dimension).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper is
CPU-only; mapping the contraction to the MXU means choosing TA/TB/TP so
that the three resident blocks fit VMEM (≈16 MiB/core on TPUv4):
``TP·TA + TP·TB + TA·TB`` floats. The defaults (128³) use 192 KiB — far
under budget, sized instead for MXU occupancy (128×128 systolic tiles).

Runs with ``interpret=True`` everywhere in this repo: the CPU PJRT plugin
cannot execute Mosaic custom-calls (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes: one MXU-shaped tile per operand.
TILE_P = 128
TILE_A = 128
TILE_B = 128


def _cooc_kernel(x_ref, y_ref, o_ref):
    """One grid step: o[i,j] (+)= x[k,i]ᵀ @ y[k,j]."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # fp32 accumulation; on real TPU hardware the operands would be cast
    # to bf16 for the MXU with an f32 accumulator — preserve_element_type
    # keeps the contraction exact for {0,1} inputs either way.
    o_ref[...] += jax.lax.dot_general(
        x_ref[...],
        y_ref[...],
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def _pick_tile(dim: int, tile: int) -> int:
    """Largest divisor tile ≤ requested tile (shapes here are powers of
    two or small; fall back to the full dim when it does not divide)."""
    if dim % tile == 0:
        return tile
    for cand in (64, 32, 16, 8, 4, 2, 1):
        if cand <= tile and dim % cand == 0:
            return cand
    return dim


@functools.partial(jax.jit, static_argnames=("tile_p", "tile_a", "tile_b"))
def cooc(x, y, *, tile_p: int = TILE_P, tile_a: int = TILE_A, tile_b: int = TILE_B):
    """Co-occurrence counts ``xᵀ @ y`` via the Pallas kernel.

    Args:
      x: f32[P, A] indicator (or weighted) matrix.
      y: f32[P, B] indicator matrix.

    Returns:
      f32[A, B] contraction over the patient dimension.
    """
    p, a = x.shape
    p2, b = y.shape
    assert p == p2, f"patient dims differ: {p} vs {p2}"
    tp = _pick_tile(p, tile_p)
    ta = _pick_tile(a, tile_a)
    tb = _pick_tile(b, tile_b)
    grid = (a // ta, b // tb, p // tp)
    return pl.pallas_call(
        _cooc_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tp, ta), lambda i, j, k: (k, i)),
            pl.BlockSpec((tp, tb), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((ta, tb), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((a, b), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, y)


def vmem_bytes(tile_p: int = TILE_P, tile_a: int = TILE_A, tile_b: int = TILE_B) -> int:
    """Estimated VMEM residency of one grid step (f32)."""
    return 4 * (tile_p * tile_a + tile_p * tile_b + tile_a * tile_b)


def mxu_utilization(tile_a: int = TILE_A, tile_b: int = TILE_B) -> float:
    """Fraction of the 128×128 MXU tile the output block occupies."""
    return min(tile_a, 128) * min(tile_b, 128) / (128.0 * 128.0)
