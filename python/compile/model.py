"""L2 — the JAX compute graph of the analytics layer.

Every function here is AOT-lowered by ``aot.py`` into an HLO-text artifact
that the Rust coordinator executes via PJRT. The co-occurrence
contraction goes through the L1 Pallas kernel (``kernels.cooc``) so it
lowers into the same HLO module; the surrounding arithmetic (MI terms,
logistic loss, correlation normalisation) is plain jnp that XLA fuses
around it.

Conventions shared with the Rust side (rust/src/runtime):

* all tensors are f32, row-major;
* the patient dimension is tiled to ``TILE_ROWS`` and features to
  ``TILE_FEATURES`` — Rust pads tiles with zeros and passes a row mask
  where the computation is mask-aware;
* gradients/counts are *sums*, accumulated across tiles by Rust, so each
  artifact is tile-local and stateless.
"""

import jax.numpy as jnp

from compile.kernels import cooc as cooc_kernel
from compile.kernels import ref

# Fixed AOT shapes (one compiled executable per shape).
TILE_ROWS = 512       # patients per tile
TILE_FEATURES = 256   # feature columns per tile


def cooc_counts(x, y):
    """Pairwise co-occurrence counts xᵀ·y via the Pallas kernel."""
    return cooc_kernel.cooc(x, y)


def cooc_label(x, y_col):
    """Feature-vs-label counts xᵀ·y for a single label column."""
    return cooc_kernel.cooc(x, y_col)


def mi_pair(n11, ci, cj, n):
    """Pairwise MI from accumulated counts (elementwise, fuses fully)."""
    return ref.mi_pair_ref(n11, ci, cj, n)


def logreg_grad(w, b, x, y, mask):
    """Tile-local logistic-regression gradients + loss (sums over rows)."""
    return ref.logreg_grad_ref(w, b, x, y, mask)


def logreg_predict(w, b, x):
    """Tile-local predicted probabilities."""
    return ref.logreg_predict_ref(w, b, x)


def corr_masked(x, t, mask):
    """Masked Pearson correlation of each feature column with target t."""
    return ref.corr_masked_ref(x, t, mask)


def artifact_specs():
    """The artifact registry: name → (function, example input shapes).

    Shapes use (rows, features) = (TILE_ROWS, TILE_FEATURES); every entry
    becomes ``artifacts/<name>.hlo.txt`` plus a manifest row consumed by
    the Rust runtime.
    """
    P, F = TILE_ROWS, TILE_FEATURES
    s = jnp.float32
    return {
        "cooc": (
            lambda x, y: (cooc_counts(x, y),),
            [(P, F), (P, F)],
        ),
        "cooc_label": (
            lambda x, y: (cooc_label(x, y),),
            [(P, F), (P, 1)],
        ),
        "mi_pair": (
            lambda n11, ci, cj, n: (mi_pair(n11, ci, cj, n),),
            [(F, F), (F, 1), (1, F), (1, 1)],
        ),
        "mi_label": (
            # label MI: same 2×2 table maths with B=1
            lambda n11, ci, cj, n: (mi_pair(n11, ci, cj, n),),
            [(F, 1), (F, 1), (1, 1), (1, 1)],
        ),
        "logreg_grad": (
            lambda w, b, x, y, m: logreg_grad(w, b, x, y, m),
            [(F, 1), (1, 1), (P, F), (P, 1), (P, 1)],
        ),
        "logreg_predict": (
            lambda w, b, x: (logreg_predict(w, b, x),),
            [(F, 1), (1, 1), (P, F)],
        ),
        "corr_masked": (
            lambda x, t, m: (corr_masked(x, t, m),),
            [(P, F), (P, 1), (P, 1)],
        ),
    }
