"""AOT compilation: lower every L2 artifact to HLO text for the Rust side.

HLO *text* (not serialized HloModuleProto) is the interchange format:
jax ≥ 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (behind the published ``xla`` crate) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage:  python -m compile.aot --out-dir ../artifacts
The output directory receives one ``<name>.hlo.txt`` per artifact plus a
``manifest.json`` describing input shapes, consumed by
``rust/src/runtime``.

Python runs ONLY here, at build time (`make artifacts`); the Rust binary
is self-contained afterwards.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    """stablehlo → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_artifact(fn, shapes):
    args = [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]
    return jax.jit(fn).lower(*args)


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts", help="artifact output directory")
    parser.add_argument("--only", default=None, help="comma-separated artifact subset")
    args = parser.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    specs = model.artifact_specs()
    if args.only:
        wanted = set(args.only.split(","))
        specs = {k: v for k, v in specs.items() if k in wanted}

    manifest = {
        "tile_rows": model.TILE_ROWS,
        "tile_features": model.TILE_FEATURES,
        "artifacts": {},
    }
    for name, (fn, shapes) in sorted(specs.items()):
        lowered = lower_artifact(fn, shapes)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        n_outputs = len(jax.eval_shape(fn, *[jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]))
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "input_shapes": [list(s) for s in shapes],
            "num_outputs": n_outputs,
        }
        print(f"wrote {path} ({len(text)} chars, {len(shapes)} inputs, {n_outputs} outputs)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {os.path.join(args.out_dir, 'manifest.json')}")


if __name__ == "__main__":
    main()
