"""AOT path: every artifact lowers to loadable HLO text.

Verifies the exact interchange the Rust runtime depends on: stablehlo →
XlaComputation → HLO text, with a tuple root holding the declared number
of outputs.
"""

import json
import subprocess
import sys
import tempfile
from pathlib import Path

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def specs():
    return model.artifact_specs()


def test_all_artifacts_lower_to_hlo_text(specs):
    for name, (fn, shapes) in specs.items():
        lowered = aot.lower_artifact(fn, shapes)
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name
        # every declared input must appear as a parameter
        for i in range(len(shapes)):
            assert f"parameter({i})" in text, (name, i)


def test_cooc_artifact_contains_contraction(specs):
    fn, shapes = specs["cooc"]
    text = aot.to_hlo_text(aot.lower_artifact(fn, shapes))
    # the Pallas kernel (interpret mode) must lower to a plain dot — no
    # Mosaic custom-call may survive into the artifact
    assert "custom-call" not in text or "Sharding" in text, "unexpected custom-call"
    assert "dot(" in text or "dot." in text or " dot" in text


def test_manifest_written_end_to_end():
    with tempfile.TemporaryDirectory() as td:
        result = subprocess.run(
            [sys.executable, "-m", "compile.aot", "--out-dir", td, "--only", "mi_label"],
            cwd=str(Path(__file__).resolve().parents[1]),
            capture_output=True,
            text=True,
            timeout=600,
        )
        assert result.returncode == 0, result.stderr
        manifest = json.loads((Path(td) / "manifest.json").read_text())
        assert "mi_label" in manifest["artifacts"]
        entry = manifest["artifacts"]["mi_label"]
        hlo = (Path(td) / entry["file"]).read_text()
        assert hlo.startswith("HloModule")
        assert entry["num_outputs"] == 1
        assert manifest["tile_rows"] == model.TILE_ROWS


def test_artifact_shapes_match_model_tiles(specs):
    P, F = model.TILE_ROWS, model.TILE_FEATURES
    assert specs["cooc"][1] == [(P, F), (P, F)]
    assert specs["logreg_grad"][1] == [(F, 1), (1, 1), (P, F), (P, 1), (P, 1)]
    assert specs["corr_masked"][1] == [(P, F), (P, 1), (P, 1)]
