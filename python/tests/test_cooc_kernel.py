"""L1 correctness: the Pallas co-occurrence kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes and value distributions; assert_allclose against
ref.cooc_ref is the core correctness signal for the kernel that every
AOT artifact embeds.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import cooc, ref


def _binary(rng, shape, density=0.3):
    return (rng.random(shape) < density).astype(np.float32)


@settings(max_examples=30, deadline=None)
@given(
    p=st.sampled_from([1, 2, 4, 8, 32, 128, 256, 512]),
    a=st.sampled_from([1, 2, 8, 64, 128, 256]),
    b=st.sampled_from([1, 4, 16, 128, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_cooc_matches_ref_binary(p, a, b, seed):
    rng = np.random.default_rng(seed)
    x = _binary(rng, (p, a))
    y = _binary(rng, (p, b))
    got = np.asarray(cooc.cooc(jnp.asarray(x), jnp.asarray(y)))
    want = np.asarray(ref.cooc_ref(x, y))
    np.testing.assert_allclose(got, want, rtol=0, atol=0)  # exact for counts


@settings(max_examples=20, deadline=None)
@given(
    p=st.sampled_from([16, 128, 384]),
    a=st.sampled_from([32, 96, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_cooc_matches_ref_real_valued(p, a, seed):
    # The kernel is also used with weighted (non-binary) features.
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((p, a)).astype(np.float32)
    y = rng.standard_normal((p, a)).astype(np.float32)
    got = np.asarray(cooc.cooc(jnp.asarray(x), jnp.asarray(y)))
    want = np.asarray(ref.cooc_ref(x, y))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-4)


def test_cooc_counts_are_integers():
    rng = np.random.default_rng(7)
    x = _binary(rng, (512, 256))
    got = np.asarray(cooc.cooc(jnp.asarray(x), jnp.asarray(x)))
    assert np.all(got == np.round(got))
    # Diagonal equals the column counts.
    np.testing.assert_array_equal(np.diag(got), x.sum(axis=0))
    # Symmetry of X^T X.
    np.testing.assert_array_equal(got, got.T)


def test_cooc_bounds():
    # Co-occurrence can never exceed either marginal count.
    rng = np.random.default_rng(11)
    x = _binary(rng, (256, 64), density=0.5)
    got = np.asarray(cooc.cooc(jnp.asarray(x), jnp.asarray(x)))
    counts = x.sum(axis=0)
    assert np.all(got <= np.minimum.outer(counts, counts) + 1e-6)


def test_non_divisible_shapes_fall_back_to_smaller_tiles():
    rng = np.random.default_rng(3)
    x = _binary(rng, (96, 48))  # 96 = 32*3, 48 = 16*3 — not 128-divisible
    y = _binary(rng, (96, 24))
    got = np.asarray(cooc.cooc(jnp.asarray(x), jnp.asarray(y)))
    want = np.asarray(ref.cooc_ref(x, y))
    np.testing.assert_allclose(got, want, atol=0)


def test_mismatched_patient_dims_rejected():
    x = jnp.zeros((8, 4))
    y = jnp.zeros((16, 4))
    with pytest.raises(AssertionError):
        cooc.cooc(x, y)


def test_vmem_estimate_within_budget():
    # The chosen AOT tiles must fit a conservative 4 MiB VMEM budget.
    assert cooc.vmem_bytes() <= 4 << 20
    assert 0.0 < cooc.mxu_utilization() <= 1.0
    # Default tiles fully occupy the MXU output tile.
    assert cooc.mxu_utilization() == 1.0
