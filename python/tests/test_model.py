"""L2 correctness: MI, logistic-regression gradients, masked correlation."""

import math

import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile import model
from compile.kernels import ref


def _mi_2x2(n11, n10, n01, n00):
    """Scalar contingency-table MI, computed the slow obvious way."""
    n = n11 + n10 + n01 + n00
    mi = 0.0
    for nab, pa, pb in [
        (n11, n11 + n10, n11 + n01),
        (n10, n11 + n10, n10 + n00),
        (n01, n01 + n00, n11 + n01),
        (n00, n01 + n00, n10 + n00),
    ]:
        if nab > 0:
            mi += (nab / n) * math.log((nab / n) / ((pa / n) * (pb / n)))
    return max(mi, 0.0)


@settings(max_examples=40, deadline=None)
@given(
    n11=st.integers(0, 50),
    n10=st.integers(0, 50),
    n01=st.integers(0, 50),
    n00=st.integers(1, 50),
)
def test_mi_pair_matches_scalar_table(n11, n10, n01, n00):
    n = float(n11 + n10 + n01 + n00)
    ci = float(n11 + n10)
    cj = float(n11 + n01)
    got = np.asarray(
        model.mi_pair(
            jnp.full((1, 1), float(n11)),
            jnp.full((1, 1), ci),
            jnp.full((1, 1), cj),
            jnp.full((1, 1), n),
        )
    )[0, 0]
    want = _mi_2x2(n11, n10, n01, n00)
    assert abs(got - want) < 1e-4, (got, want)


def test_mi_identical_variables_is_entropy():
    # MI(X; X) = H(X); for p=0.5, H = ln 2.
    n = 1000.0
    c = 500.0
    got = np.asarray(
        model.mi_pair(
            jnp.full((1, 1), c), jnp.full((1, 1), c), jnp.full((1, 1), c), jnp.full((1, 1), n)
        )
    )[0, 0]
    assert abs(got - math.log(2)) < 1e-4


def test_mi_independent_variables_is_zero():
    # Exactly factorised table: n11/n = (ci/n)(cj/n).
    got = np.asarray(
        model.mi_pair(
            jnp.full((1, 1), 25.0),
            jnp.full((1, 1), 50.0),
            jnp.full((1, 1), 50.0),
            jnp.full((1, 1), 100.0),
        )
    )[0, 0]
    assert abs(got) < 1e-5


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_logreg_grad_matches_finite_differences(seed):
    rng = np.random.default_rng(seed)
    P, F = 32, 8
    w = rng.standard_normal((F, 1)).astype(np.float32) * 0.1
    b = rng.standard_normal((1, 1)).astype(np.float32) * 0.1
    x = (rng.random((P, F)) < 0.4).astype(np.float32)
    y = (rng.random((P, 1)) < 0.5).astype(np.float32)
    mask = np.ones((P, 1), np.float32)
    mask[P // 2 :] = rng.integers(0, 2, (P - P // 2, 1))

    gw, gb, loss = [np.asarray(v) for v in model.logreg_grad(w, b, x, y, mask)]

    def loss_at(wv, bv):
        z = x @ wv + bv
        vec = np.maximum(z, 0) - z * y + np.log1p(np.exp(-np.abs(z)))
        return float((vec * mask).sum())

    eps = 1e-3
    for idx in [(0, 0), (F // 2, 0), (F - 1, 0)]:
        wp = w.copy()
        wp[idx] += eps
        wm = w.copy()
        wm[idx] -= eps
        fd = (loss_at(wp, b) - loss_at(wm, b)) / (2 * eps)
        assert abs(fd - gw[idx]) < 5e-2, (idx, fd, gw[idx])
    fd_b = (loss_at(w, b + eps) - loss_at(w, b - eps)) / (2 * eps)
    assert abs(fd_b - gb[0, 0]) < 5e-2
    assert abs(loss[0, 0] - loss_at(w, b)) < 1e-2


def test_logreg_predict_probabilities():
    w = np.array([[10.0], [-10.0]], np.float32)
    b = np.zeros((1, 1), np.float32)
    x = np.array([[1, 0], [0, 1], [0, 0]], np.float32)
    p = np.asarray(model.logreg_predict(w, b, x))
    assert p[0, 0] > 0.99 and p[1, 0] < 0.01 and abs(p[2, 0] - 0.5) < 1e-6


def test_masked_rows_do_not_affect_gradients():
    rng = np.random.default_rng(0)
    P, F = 16, 4
    w = rng.standard_normal((F, 1)).astype(np.float32)
    b = np.zeros((1, 1), np.float32)
    x = (rng.random((P, F)) < 0.5).astype(np.float32)
    y = (rng.random((P, 1)) < 0.5).astype(np.float32)
    mask = np.ones((P, 1), np.float32)
    mask[8:] = 0.0
    g1 = [np.asarray(v) for v in model.logreg_grad(w, b, x, y, mask)]
    # Garbage in the masked rows must not change anything.
    x2 = x.copy()
    x2[8:] = 1.0
    y2 = y.copy()
    y2[8:] = 1.0
    g2 = [np.asarray(v) for v in model.logreg_grad(w, b, x2, y2, mask)]
    for a, c in zip(g1, g2):
        np.testing.assert_allclose(a, c, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_corr_matches_numpy(seed):
    rng = np.random.default_rng(seed)
    P, F = 64, 8
    x = rng.standard_normal((P, F)).astype(np.float32)
    t = rng.standard_normal((P, 1)).astype(np.float32)
    mask = np.ones((P, 1), np.float32)
    got = np.asarray(model.corr_masked(x, t, mask)).ravel()
    for f in range(F):
        want = np.corrcoef(x[:, f], t[:, 0])[0, 1]
        assert abs(got[f] - want) < 1e-4, (f, got[f], want)


def test_corr_masked_ignores_invalid_rows():
    rng = np.random.default_rng(1)
    P, F = 32, 4
    x = rng.standard_normal((P, F)).astype(np.float32)
    t = rng.standard_normal((P, 1)).astype(np.float32)
    mask = np.ones((P, 1), np.float32)
    mask[20:] = 0.0
    got = np.asarray(model.corr_masked(x, t, mask)).ravel()
    for f in range(F):
        want = np.corrcoef(x[:20, f], t[:20, 0])[0, 1]
        assert abs(got[f] - want) < 1e-4


def test_corr_constant_column_is_zero():
    P = 16
    x = np.ones((P, 2), np.float32)
    x[:, 1] = np.arange(P)
    t = np.arange(P, dtype=np.float32).reshape(P, 1)
    mask = np.ones((P, 1), np.float32)
    got = np.asarray(model.corr_masked(x, t, mask)).ravel()
    assert abs(got[0]) < 1e-6          # constant column → 0 by convention
    assert abs(got[1] - 1.0) < 1e-4    # perfectly correlated


def test_cooc_counts_uses_kernel_and_matches_ref():
    rng = np.random.default_rng(5)
    x = (rng.random((model.TILE_ROWS, model.TILE_FEATURES)) < 0.2).astype(np.float32)
    got = np.asarray(model.cooc_counts(jnp.asarray(x), jnp.asarray(x)))
    want = np.asarray(ref.cooc_ref(x, x))
    np.testing.assert_allclose(got, want, atol=0)
